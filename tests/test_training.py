"""Training substrate tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.training import AdamWConfig, train
from repro.training.checkpoint import (
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.data import SyntheticEmbeds, SyntheticLM
from repro.training.optimizer import (
    adamw_update,
    global_norm,
    init_adamw,
    lr_schedule,
)


def test_lr_schedule():
    c = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(lr_schedule(c, jnp.array(0))) == 0.0
    assert float(lr_schedule(c, jnp.array(10))) == pytest.approx(1e-3)
    assert float(lr_schedule(c, jnp.array(100))) == pytest.approx(1e-4)


def test_adamw_moves_toward_gradient():
    c = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    state = init_adamw(params)
    new_p, state, m = adamw_update(c, params, grads, state)
    assert np.all(np.asarray(new_p["w"]) < 1.0)
    assert float(m["grad_norm"]) == pytest.approx(2.0)
    assert int(state["count"]) == 1


def test_grad_clipping():
    c = AdamWConfig(lr=0.1, grad_clip=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros((100,))}
    grads = {"w": jnp.full((100,), 100.0)}
    state = init_adamw(params)
    _, _, m = adamw_update(c, params, grads, state)
    assert float(m["grad_norm"]) == pytest.approx(1000.0)
    # effective update uses the clipped gradient
    assert float(global_norm(state["m"])) <= 0.11 * 1000


def test_data_pipeline_deterministic_and_seekable():
    d = SyntheticLM(1000, 32, 4, seed=1)
    b1 = d.batch_at(7)
    b2 = d.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # labels are next-token shifted
    full = d.batch_at(3)
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["labels"][:, :-1])
    e = SyntheticEmbeds(64, 100, 16, 2)
    be = e.batch_at(0)
    assert be["embeds"].shape == (2, 16, 64)


def test_train_loss_decreases():
    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=2)
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    out = train(cfg, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30),
                iter(data), 30, log_every=29, log_fn=lambda *_: None)
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"]


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), tree, step=5)
    assert latest_step(str(tmp_path)) == 5
    restored = load_checkpoint(str(tmp_path), tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
