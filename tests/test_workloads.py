"""Workload generators, the unified run loop, and queueing semantics."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import (
    EventTimeline,
    InterferenceEvent,
    SimTimeSource,
    generate_events,
    optimal_partition,
    pipelined_latency,
    serial_latency,
    simulate,
    synthetic_database,
    throughput,
)
from repro.schedulers import RebalanceRuntime, make_scheduler
from repro.workloads import (
    BurstyWorkload,
    DiurnalWorkload,
    PipelineTrace,
    PoissonWorkload,
    RampWorkload,
    TraceWorkload,
    Workload,
    available_workloads,
    make_workload,
    register_workload,
    unregister_workload,
)

BUILTINS = ("closed", "poisson", "bursty", "diurnal", "ramp", "trace")


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_builtins():
    names = available_workloads()
    for name in BUILTINS:
        assert name in names


def test_registry_kwargs_filtered_per_workload():
    """One kwargs superset constructs any workload (closed ignores rate)."""
    for name in ("closed", "poisson", "bursty"):
        wl = make_workload(name, rate=2.0, burst_rate=5.0, seed=3)
        assert isinstance(wl, Workload)
    assert make_workload("poisson", rate=2.0, burst_rate=9.9).rate == 2.0


def test_registry_unknown_and_required():
    with pytest.raises(ValueError, match="unknown workload"):
        make_workload("does-not-exist")
    with pytest.raises(TypeError):
        make_workload("trace")         # inter_arrivals is required


def test_register_custom_workload():
    @register_workload("_test_uniform", gap=2.0)
    class UniformWorkload:
        open_loop = True

        def __init__(self, gap):
            self.gap = gap

        def inter_arrivals(self, n):
            return np.full(n, self.gap)

    try:
        wl = make_workload("_test_uniform")
        assert wl.gap == 2.0           # registration default applied
        assert wl.name == "_test_uniform"
        with pytest.raises(ValueError, match="already registered"):
            register_workload("_test_uniform")(UniformWorkload)
    finally:
        unregister_workload("_test_uniform")
    with pytest.raises(ValueError):
        make_workload("_test_uniform")


# ---------------------------------------------------------------------------
# generators: seeded determinism + rate sanity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wl_factory", [
    lambda seed: PoissonWorkload(rate=3.0, seed=seed),
    lambda seed: BurstyWorkload(burst_rate=8.0, base_rate=1.0,
                                mean_burst=2.0, mean_gap=3.0, seed=seed),
    lambda seed: DiurnalWorkload(mean_rate=4.0, period=50.0,
                                 amplitude=0.7, seed=seed),
    lambda seed: RampWorkload(start_rate=1.0, end_rate=8.0,
                              ramp_time=30.0, seed=seed),
])
def test_open_loop_generators_seeded_deterministic(wl_factory):
    a = wl_factory(7).inter_arrivals(500)
    b = wl_factory(7).inter_arrivals(500)
    c = wl_factory(8).inter_arrivals(500)
    assert np.array_equal(a, b)        # same seed -> identical
    assert not np.array_equal(a, c)    # different seed -> different
    assert np.all(a >= 0)
    # repeated calls on ONE instance are also identical (replayable)
    wl = wl_factory(7)
    assert np.array_equal(wl.inter_arrivals(500), a)


@given(st.floats(0.5, 50.0), st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_poisson_mean_rate(rate, seed):
    gaps = PoissonWorkload(rate=rate, seed=seed).inter_arrivals(4000)
    # mean inter-arrival ~ 1/rate (4000 samples: s.e. ~ 1.6%)
    assert gaps.mean() == pytest.approx(1.0 / rate, rel=0.12)


@given(st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_bursty_long_run_rate(seed):
    burst_rate, base_rate, mean_burst, mean_gap = 20.0, 2.0, 5.0, 10.0
    wl = BurstyWorkload(burst_rate=burst_rate, base_rate=base_rate,
                        mean_burst=mean_burst, mean_gap=mean_gap, seed=seed)
    gaps = wl.inter_arrivals(6000)
    expected = ((mean_burst * burst_rate + mean_gap * base_rate)
                / (mean_burst + mean_gap))
    observed = 1.0 / gaps.mean()
    assert observed == pytest.approx(expected, rel=0.35)
    # rate must sit strictly between the two phase rates
    assert base_rate < observed < burst_rate


def test_bursty_pure_onoff_has_silent_gaps():
    wl = BurstyWorkload(burst_rate=50.0, base_rate=0.0,
                        mean_burst=1.0, mean_gap=5.0, seed=1)
    gaps = wl.inter_arrivals(2000)
    # OFF phases (mean 5) appear as inter-arrival gaps far above the
    # in-burst mean (0.02)
    assert gaps.max() > 1.0
    assert np.median(gaps) < 0.1


@given(st.floats(1.0, 20.0), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_diurnal_long_run_mean_rate(mean_rate, seed):
    """Over whole cycles the sinusoid integrates out: the long-run rate
    is ``mean_rate`` regardless of amplitude/phase."""
    wl = DiurnalWorkload(mean_rate=mean_rate, period=20.0 / mean_rate,
                         amplitude=0.8, phase=1.3, seed=seed)
    gaps = wl.inter_arrivals(5000)
    assert np.all(gaps >= 0)
    assert 1.0 / gaps.mean() == pytest.approx(mean_rate, rel=0.12)


def test_diurnal_peak_vs_trough_density():
    """Arrivals crowd the sinusoid's peak quarter-cycle and thin out in
    the trough — the day/night swing routers must ride."""
    period = 100.0
    wl = DiurnalWorkload(mean_rate=5.0, period=period, amplitude=0.8,
                         seed=3)
    t = np.cumsum(wl.inter_arrivals(6000))
    phase = t % period
    peak = np.sum((phase > 15) & (phase < 35))      # sin max at t=25
    trough = np.sum((phase > 65) & (phase < 85))    # sin min at t=75
    # rate ratio at amplitude 0.8 is (1.8 / 0.2) = 9; demand a wide gap
    assert peak > 3 * trough


def test_diurnal_validation():
    with pytest.raises(ValueError, match="amplitude"):
        DiurnalWorkload(mean_rate=1.0, period=10.0, amplitude=1.0)
    with pytest.raises(ValueError, match="mean_rate"):
        DiurnalWorkload(mean_rate=0.0, period=10.0)
    with pytest.raises(ValueError, match="period"):
        DiurnalWorkload(mean_rate=1.0, period=0.0)


@given(st.floats(2.0, 20.0), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_ramp_settles_at_end_rate(end_rate, seed):
    wl = RampWorkload(start_rate=end_rate / 4, end_rate=end_rate,
                      ramp_time=10.0, seed=seed)
    t = np.cumsum(wl.inter_arrivals(4000))
    tail = t[t > 10.0]          # post-ramp: homogeneous at end_rate
    assert len(tail) > 100
    observed = (len(tail) - 1) / (tail[-1] - tail[0])
    assert observed == pytest.approx(end_rate, rel=0.15)


def test_ramp_density_increases_during_ramp_up():
    wl = RampWorkload(start_rate=1.0, end_rate=10.0, ramp_time=60.0,
                      seed=5)
    t = np.cumsum(wl.inter_arrivals(4000))
    early = np.sum(t < 15.0)                  # mean rate ~2.1
    late = np.sum((t > 45.0) & (t < 60.0))    # mean rate ~8.9
    assert late > 2 * early
    with pytest.raises(ValueError, match="ramp_time"):
        RampWorkload(start_rate=1.0, end_rate=2.0, ramp_time=0.0)
    with pytest.raises(ValueError, match="at least one"):
        RampWorkload(start_rate=0.0, end_rate=0.0, ramp_time=1.0)


def test_diurnal_and_ramp_drive_the_simulator(db):
    """The new generators plug into the same run loop: queueing
    decomposition holds and the workload name lands on the trace."""
    for name, kw in (("diurnal", dict(mean_rate=0.02, period=5000.0,
                                      amplitude=0.6, seed=1)),
                     ("ramp", dict(start_rate=0.002, end_rate=0.02,
                                   ramp_time=5000.0, seed=1))):
        r = simulate(db, 4, scheduler="odin", num_queries=300,
                     freq_period=50, duration=25, seed=1,
                     workload=name, workload_kwargs=kw)
        assert r.workload == name
        assert np.allclose(r.latencies,
                           r.queue_delays + r.service_latencies)


def test_trace_workload_replays_and_cycles():
    src = [0.5, 1.0, 0.25]
    wl = TraceWorkload(src)
    assert np.array_equal(wl.inter_arrivals(3), src)
    assert np.array_equal(wl.inter_arrivals(7),
                          [0.5, 1.0, 0.25, 0.5, 1.0, 0.25, 0.5])
    with pytest.raises(ValueError):
        TraceWorkload([])
    with pytest.raises(ValueError):
        TraceWorkload([0.1, -0.2])


# ---------------------------------------------------------------------------
# closed-loop bit-compatibility with the pre-workloads simulate()
# ---------------------------------------------------------------------------


def _reference_closed_loop(db, num_eps, scheduler, alpha, num_queries,
                           freq_period, duration, seed):
    """The pre-refactor simulate() loop, transcribed verbatim (PR 1
    state): one query per tick, back-to-back, dict-overwrite event
    activation.  Valid as a reference for non-overlapping settings."""
    events = generate_events(num_queries, num_eps, db.num_scenarios,
                             freq_period, duration, seed)
    opt_cfg, _ = optimal_partition(db, [0] * num_eps, num_eps)
    config = list(opt_cfg)
    scenarios = [0] * num_eps
    source = SimTimeSource(db, scenarios)
    policy = make_scheduler(scheduler, alpha=alpha, rel_threshold=0.02)
    runtime = RebalanceRuntime(policy, config)
    latencies = np.zeros(num_queries)
    throughputs = np.zeros(num_queries)
    serial_mask = np.zeros(num_queries, dtype=bool)
    configs_trace = []
    for q in range(num_queries):
        active = {}
        for ev in events:
            if ev.start <= q < ev.end:
                active[ev.ep] = ev.scenario
        new_scen = [active.get(ep, 0) for ep in range(num_eps)]
        if new_scen != scenarios:
            scenarios[:] = new_scen
            source.scenarios[:] = new_scen
        step = runtime.poll(source)
        times = source.stage_times(step.config)
        latencies[q] = (serial_latency(times) if step.serial
                        else pipelined_latency(times))
        throughputs[q] = throughput(times)
        serial_mask[q] = step.serial
        configs_trace.append(list(step.config))
    return latencies, throughputs, serial_mask, configs_trace, runtime


@pytest.mark.parametrize("scheduler", ["odin", "lls", "none"])
def test_closed_loop_bit_compatible_with_pre_refactor(db, scheduler):
    kw = dict(num_queries=400, freq_period=20, duration=10, seed=3)
    lat, thr, serial, cfgs, rt = _reference_closed_loop(
        db, 4, scheduler, alpha=4, **kw)
    r = simulate(db, 4, scheduler=scheduler, alpha=4, workload="closed",
                 **kw)
    assert np.array_equal(r.latencies, lat)          # exact, not approx
    assert np.array_equal(r.throughputs, thr)
    assert np.array_equal(r.serial_mask, serial)
    assert r.configs_trace == cfgs
    assert r.num_rebalances == rt.num_rebalances
    assert r.total_trials == rt.total_trials
    assert r.mitigation_lengths == rt.mitigation_lengths
    # the closed loop queues nothing and the default workload is closed
    assert np.all(r.queue_delays == 0)
    assert np.array_equal(r.service_latencies, r.latencies)
    r_default = simulate(db, 4, scheduler=scheduler, alpha=4, **kw)
    assert np.array_equal(r_default.latencies, r.latencies)
    assert r_default.workload == "closed"


# ---------------------------------------------------------------------------
# event advancer: deterministic overlap rule
# ---------------------------------------------------------------------------


def test_event_overlap_max_severity_wins():
    evs = [InterferenceEvent(start=0, duration=100, ep=1, scenario=3),
           InterferenceEvent(start=10, duration=50, ep=1, scenario=7),
           InterferenceEvent(start=20, duration=20, ep=0, scenario=2)]
    severity = [0.0] * 12
    severity[3 - 1] = 2.5           # scenario 3 outranks scenario 7
    severity[7 - 1] = 1.2
    severity[2 - 1] = 9.0
    tl = EventTimeline(evs, num_eps=4, severity=severity)
    assert tl.scenarios_at(5) == [0, 3, 0, 0]
    # both active on EP1: severity rule keeps 3, NOT last-wins 7
    assert tl.scenarios_at(30) == [2, 3, 0, 0]
    assert tl.scenarios_at(70) == [0, 3, 0, 0]   # 7 expired
    assert tl.scenarios_at(99) == [0, 3, 0, 0]
    assert tl.scenarios_at(100) == [0, 0, 0, 0]


def test_event_overlap_severity_tie_breaks_on_scenario_index():
    evs = [InterferenceEvent(start=0, duration=50, ep=0, scenario=2),
           InterferenceEvent(start=0, duration=50, ep=0, scenario=5)]
    tl = EventTimeline(evs, num_eps=1, severity=[1.0] * 12)
    assert tl.scenarios_at(10) == [5]
    # order of the event list must not matter
    tl_rev = EventTimeline(list(reversed(evs)), num_eps=1,
                           severity=[1.0] * 12)
    assert tl_rev.scenarios_at(10) == [5]


def test_event_default_severity_ranks_by_scenario_index():
    evs = [InterferenceEvent(start=0, duration=10, ep=0, scenario=4),
           InterferenceEvent(start=0, duration=10, ep=0, scenario=9)]
    assert EventTimeline(evs, num_eps=1).scenarios_at(0) == [9]


def test_paper_heavy_overlap_setting_is_deterministic(db):
    """freq=2, dur=100 stacks ~50 concurrent events; the run must be
    reproducible and rank overlaps by database severity."""
    kw = dict(num_queries=300, freq_period=2, duration=100, seed=5)
    r1 = simulate(db, 4, scheduler="odin", alpha=4, **kw)
    r2 = simulate(db, 4, scheduler="odin", alpha=4, **kw)
    assert np.array_equal(r1.latencies, r2.latencies)
    assert r1.configs_trace == r2.configs_trace
    # the advancer's pick agrees with a direct EventTimeline replay
    events = generate_events(300, 4, db.num_scenarios, 2, 100, 5)
    tl = EventTimeline(events, 4, severity=db.scenario_severities())
    sev = db.scenario_severities()
    for q in (50, 150, 250):
        scen = tl.scenarios_at(q)
        for ep in range(4):
            concurrent = [e.scenario for e in events
                          if e.ep == ep and e.start <= q < e.end]
            if concurrent:
                best = max(concurrent,
                           key=lambda s: (sev[s - 1], s))
                assert scen[ep] == best
            else:
                assert scen[ep] == 0


# ---------------------------------------------------------------------------
# time-indexed (wall-clock anchored) interference windows
# ---------------------------------------------------------------------------


def test_event_timeline_time_indexed_edges():
    evs = [InterferenceEvent(start=2.5, duration=5.0, ep=0, scenario=3)]
    tl = EventTimeline(evs, num_eps=2, time_indexed=True)
    assert tl.scenarios_at(0.0) == [0, 0]
    assert tl.scenarios_at(2.5) == [3, 0]
    assert tl.scenarios_at(7.4999) == [3, 0]
    assert tl.scenarios_at(7.5) == [0, 0]
    assert tl.next_change(0.0) == 2.5
    assert tl.next_change(2.5) == 7.5
    assert tl.next_change(7.5) == float("inf")


def test_events_for_replica_selects_scoped_and_fleet_wide():
    from repro.core import events_for_replica
    evs = [InterferenceEvent(start=0, duration=10, ep=0, scenario=1,
                             replica=2),
           InterferenceEvent(start=5, duration=10, ep=1, scenario=2),
           InterferenceEvent(start=8, duration=10, ep=2, scenario=3,
                             replica=0)]
    assert events_for_replica(evs, 2) == [evs[0], evs[1]]
    assert events_for_replica(evs, 0) == [evs[1], evs[2]]
    assert events_for_replica(evs, 1) == [evs[1]]


def test_time_indexed_events_anchor_on_arrival_clock(db):
    """A wall-clock event window hits exactly the queries whose
    arrivals fall inside it — however many that happens to be."""
    cap = simulate(db, 4, scheduler="none", events=[],
                   num_queries=10).peak_throughput
    wl = dict(rate=0.5 * cap, seed=3)
    kw = dict(num_queries=300, workload="poisson", workload_kwargs=wl)
    base = simulate(db, 4, scheduler="none", events=[], **kw)
    t0, t1 = 10000.0, 25000.0
    evs = [InterferenceEvent(start=t0, duration=t1 - t0, ep=1,
                             scenario=12)]
    r = simulate(db, 4, scheduler="none", events=evs,
                 events_time_indexed=True, **kw)
    # exogenous arrivals: identical clocks in both runs
    assert np.array_equal(r.arrival_times, base.arrival_times)
    in_win = (r.arrival_times >= t0) & (r.arrival_times < t1)
    assert 0 < in_win.sum() < len(in_win)
    # scenario 12 (max membw stressor) slows EP1's stage past the
    # bottleneck: every in-window query is served slower, no other is
    assert np.all(r.service_latencies[in_win]
                  > base.service_latencies[in_win])
    assert np.array_equal(r.service_latencies[~in_win],
                          base.service_latencies[~in_win])
    # the chunked fast path takes the same time-indexed segments
    r_scalar = simulate(db, 4, scheduler="none", events=evs,
                        events_time_indexed=True, chunking=False, **kw)
    assert np.allclose(r.latencies, r_scalar.latencies, rtol=1e-9)


def test_time_indexed_events_reject_closed_loop_and_default_events(db):
    evs = [InterferenceEvent(start=0.0, duration=10.0, ep=0, scenario=1)]
    with pytest.raises(ValueError, match="open-loop"):
        simulate(db, 4, scheduler="none", events=evs,
                 events_time_indexed=True, num_queries=10)
    with pytest.raises(ValueError, match="explicit"):
        simulate(db, 4, scheduler="none", events=None,
                 events_time_indexed=True, num_queries=10)


# ---------------------------------------------------------------------------
# open-loop queueing semantics through the unified loop
# ---------------------------------------------------------------------------


def test_open_loop_overload_queues_underload_does_not(db):
    kw = dict(num_queries=400, freq_period=50, duration=25, seed=1)
    cap = simulate(db, 4, scheduler="none", events=[],
                   num_queries=10).peak_throughput
    over = simulate(db, 4, scheduler="odin", workload="poisson",
                    workload_kwargs=dict(rate=2.0 * cap, seed=7), **kw)
    under = simulate(db, 4, scheduler="odin", workload="poisson",
                     workload_kwargs=dict(rate=0.1 * cap, seed=7), **kw)
    # queueing delay is reported distinct from service time, and
    # total latency decomposes exactly
    assert np.allclose(over.latencies,
                       over.queue_delays + over.service_latencies)
    assert over.mean_queue_delay > 100 * max(under.mean_queue_delay, 1e-12)
    assert over.queue_depths.max() > under.queue_depths.max()
    # offered load: ~what was requested; achieved saturates at capacity
    assert over.offered_load == pytest.approx(2.0 * cap, rel=0.15)
    assert over.achieved_load < 1.2 * cap
    assert under.achieved_load == pytest.approx(under.offered_load,
                                                rel=0.05)


def test_open_loop_service_latency_matches_closed_loop_model(db):
    """Arrivals change *queueing*, not the per-query service model: on
    the same seed the pipelined/serial service latencies coincide with
    the closed-loop run wherever the config traces agree."""
    kw = dict(num_queries=200, freq_period=20, duration=10, seed=3)
    closed = simulate(db, 4, scheduler="none", **kw)
    opened = simulate(db, 4, scheduler="none", workload="poisson",
                      workload_kwargs=dict(rate=1.0, seed=0), **kw)
    assert np.array_equal(opened.service_latencies, closed.latencies)
    assert opened.workload == "poisson"


def test_bursty_load_profile_shows_burst_and_drain(db):
    cap = simulate(db, 4, scheduler="none", events=[],
                   num_queries=10).peak_throughput
    r = simulate(db, 4, scheduler="odin", num_queries=400,
                 freq_period=50, duration=25, seed=1, workload="bursty",
                 workload_kwargs=dict(burst_rate=3 * cap,
                                      base_rate=0.1 * cap,
                                      mean_burst=2000, mean_gap=4000,
                                      seed=3))
    t, offered, achieved = r.load_profile(10)
    assert len(t) == len(offered) == len(achieved) == 10
    # overall arrivals == overall completions == num_queries
    width = t[1] - t[0]
    assert int(round(offered.sum() * width)) == 400
    assert int(round(achieved.sum() * width)) == 400
    # some window must show the queue growing (offered > achieved)
    assert np.any(offered > achieved + 1e-12)
    assert r.mean_queue_delay > 0


def test_serial_trials_wait_for_pipeline_drain(db):
    """A serial (exploration-trial) query runs on the drained pipeline:
    it cannot start before every previously admitted query completes."""
    cap = simulate(db, 4, scheduler="none", events=[],
                   num_queries=10).peak_throughput
    r = simulate(db, 4, scheduler="odin", alpha=4, num_queries=300,
                 freq_period=20, duration=20, seed=3, workload="poisson",
                 workload_kwargs=dict(rate=0.9 * cap, seed=5))
    assert r.serial_mask.any()
    starts = r.completion_times - r.service_latencies
    for q in np.flatnonzero(r.serial_mask):
        if q == 0:
            continue
        assert starts[q] >= r.completion_times[:q].max() - 1e-9


def test_run_pipeline_rejects_kwargs_with_instance(db):
    with pytest.raises(ValueError, match="workload_kwargs"):
        simulate(db, 4, scheduler="none", num_queries=10,
                 workload=PoissonWorkload(rate=1.0),
                 workload_kwargs=dict(rate=2.0))


def test_trace_slo_and_percentiles_available(db):
    r = simulate(db, 4, scheduler="odin", num_queries=300,
                 freq_period=20, duration=20, seed=7)
    s = r.summary()
    for key in ("p50_latency_s", "p99_latency_s", "slo_violations",
                "mean_queue_delay_s", "offered_load_qps",
                "achieved_load_qps"):
        assert key in s
    assert 0.0 <= s["slo_violations"] <= 1.0
    assert isinstance(r, PipelineTrace)
    # resource-constrained SLO reference exists for simulator traces
    assert r.slo_violations(0.9, "resource_constrained") >= 0.0
