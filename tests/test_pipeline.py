"""Pipeline executor + SPMD schedule tests."""
import dataclasses
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.pipeline import LocalPipelineExecutor, MeasuredTimeSource, stage_bounds


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), num_layers=6)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    return cfg, model, params


def test_stage_bounds():
    assert stage_bounds([2, 0, 3]) == [(0, 2), (2, 2), (2, 5)]


def test_executor_matches_model(setup):
    """Pipeline-partitioned execution == monolithic forward, any config."""
    cfg, model, params = setup
    ex = LocalPipelineExecutor(cfg, params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                cfg.vocab_size)
    ref_logits, _ = model.forward(params, tokens=tokens)
    for config in ([2, 2, 2], [1, 3, 2], [6], [3, 0, 3], [1, 1, 1, 1, 1, 1]):
        logits, times = ex.run_query(tokens, config)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits),
                                   atol=1e-4, rtol=1e-4)
        assert times.shape == (len(config),)
        assert np.all(times[np.asarray(config) > 0] > 0)


def test_executor_no_recompile_across_configs(setup):
    """Dynamic boundaries: one compiled stage_fn serves every config."""
    cfg, model, params = setup
    ex = LocalPipelineExecutor(cfg, params)
    tokens = jnp.zeros((1, 32), jnp.int32)
    ex.run_query(tokens, [3, 3])
    n0 = ex._stage_fn._cache_size()
    for config in ([2, 4], [1, 5], [6, 0], [4, 2]):
        ex.run_query(tokens, config)
    assert ex._stage_fn._cache_size() == n0


def test_measured_time_source():
    src = MeasuredTimeSource(np.array([1.0, 2.0, 3.0, 4.0]),
                             np.array([1.0, 2.0]))
    t = src.stage_times([2, 2])
    assert t[0] == pytest.approx(3.0)
    assert t[1] == pytest.approx(14.0)   # (3+4) * 2.0


def test_spmd_pipeline_subprocess():
    """GPipe shard_map schedule on 4 host devices == monolithic forward,
    incl. uneven and empty-stage configs (run in a subprocess because
    XLA_FLAGS must be set before JAX initializes)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import Model
from repro.models.layers import embed
import repro.models.blocks as blk
from repro.pipeline.spmd import pipelined_forward
from repro.launch.mesh import make_stage_mesh

cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), num_layers=8)
model = Model(cfg)
params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
mesh = make_stage_mesh(4)
B, S, M = 2, 32, 4
tokens = jax.random.randint(jax.random.PRNGKey(1), (M, B, S), 0,
                            cfg.vocab_size)
inputs = jax.vmap(lambda t: embed(params["embed"], t))(tokens)
pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

def ref(t):
    x = embed(params["embed"], t)
    def body(c, bp):
        h, _ = blk.block_forward(bp, cfg, c, pos)
        return h, None
    h, _ = jax.lax.scan(body, x, params["blocks"])
    return h
refs = np.stack([np.asarray(ref(tokens[m])) for m in range(M)])
for config in ([2,2,2,2], [1,3,2,2], [3,0,3,2]):
    with mesh:
        out = pipelined_forward(cfg, mesh, params["blocks"], config,
                                inputs, cap=4)
    err = np.max(np.abs(np.asarray(out) - refs))
    assert err < 1e-4, (config, err)
print("OK")
"""
    root = pathlib.Path(__file__).resolve().parents[1]
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True,
                       env={"PYTHONPATH": str(root / "src"),
                            "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
                            "HOME": os.environ.get("HOME", "/tmp"),
                            # host-device run: skip accelerator probing
                            "JAX_PLATFORMS": "cpu"}, cwd=str(root))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
