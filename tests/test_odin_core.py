"""Unit + property tests for the paper-faithful ODIN core."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import (
    SimTimeSource,
    balanced_config,
    brute_force_partition,
    lls_rebalance,
    odin_rebalance,
    optimal_partition,
    paper_scenarios,
    pipelined_latency,
    serial_latency,
    synthetic_database,
    throughput,
    utilization,
    waiting_times,
)


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


# ---------------------------------------------------------------------------
# database
# ---------------------------------------------------------------------------


def test_database_shape(db):
    assert db.num_layers == 16
    assert db.num_scenarios == 12            # paper Table 1
    assert np.all(db.table > 0)
    # interference can only slow layers down
    assert np.all(db.table[:, 1:] >= db.table[:, :1])


def test_database_roundtrip(tmp_path, db):
    p = str(tmp_path / "db.json")
    db.save(p)
    from repro.core import LayerDatabase
    db2 = LayerDatabase.load(p)
    np.testing.assert_allclose(db.table, db2.table)
    assert db2.scenario_names == db.scenario_names


def test_scenarios_match_paper_table1():
    scens = paper_scenarios()
    assert len(scens) == 12
    assert {s.stressor for s in scens} == {"cpu", "membw"}
    # Fig. 4 impact range: ~1.05x to ~3.5x
    assert min(s.slowdown_mean for s in scens) > 1.0
    assert max(s.slowdown_mean for s in scens) <= 3.5


# ---------------------------------------------------------------------------
# throughput / latency model
# ---------------------------------------------------------------------------


def test_throughput_is_bottleneck_reciprocal():
    assert throughput(np.array([2.0, 4.0, 1.0])) == 0.25


def test_waiting_times_recurrence():
    t = np.array([3.0, 1.0, 2.0])
    w = waiting_times(t)
    assert w[0] == 0.0
    assert w[1] == 2.0          # w1 = w0 + t0 - t1
    assert w[2] == 1.0          # w2 = w1 + t1 - t2
    v = utilization(t)
    assert v[0] == 1.0
    assert np.all((0 <= v) & (v <= 1))


def test_latency_models():
    t = np.array([1.0, 1.0, 1.0])
    assert pipelined_latency(t) == pytest.approx(3.0)
    assert serial_latency(t) == pytest.approx(3.0)
    t = np.array([4.0, 1.0, 1.0])
    # stalls behind the bottleneck add waiting
    assert pipelined_latency(t) > serial_latency(t)


# ---------------------------------------------------------------------------
# oracle
# ---------------------------------------------------------------------------


def test_dp_matches_brute_force(db):
    for scen in ([0] * 4, [12, 0, 0, 0], [0, 3, 0, 7]):
        c1, t1 = optimal_partition(db, scen, 4)
        c2, t2 = brute_force_partition(db, scen, 4)
        assert t1 == pytest.approx(t2)
        assert sum(c1) == db.num_layers


@given(st.lists(st.integers(0, 12), min_size=2, max_size=5),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_dp_optimal_property(scenarios, seed):
    db = synthetic_database("vgg16", seed=seed % 1000)
    n = len(scenarios)
    cfg, t_opt = optimal_partition(db, scenarios, n)
    assert sum(cfg) == db.num_layers
    # no balanced or random config may beat the DP optimum
    src = SimTimeSource(db, scenarios)
    assert throughput(src.stage_times(balanced_config(16, n))) <= t_opt + 1e-12


# ---------------------------------------------------------------------------
# ODIN Algorithm 1
# ---------------------------------------------------------------------------


def test_odin_improves_under_interference(db):
    cfg0, peak = optimal_partition(db, [0] * 4, 4)
    src = SimTimeSource(db, [12, 0, 0, 0])
    degraded = throughput(src.stage_times(cfg0))
    res = odin_rebalance(cfg0, 10, src)
    assert res.throughput > degraded
    assert sum(res.config) == db.num_layers


def test_odin_trial_counts_match_paper(db):
    """Paper §4.2: ~4 serial queries for alpha=2, ~12 for alpha=10."""
    cfg0, _ = optimal_partition(db, [0] * 4, 4)
    counts = {2: [], 10: []}
    for alpha in (2, 10):
        for ep in range(4):
            for scen in (4, 8, 12):
                s = [0] * 4
                s[ep] = scen
                res = odin_rebalance(cfg0, alpha, SimTimeSource(db, s))
                counts[alpha].append(res.num_trials)
    assert 2 <= np.mean(counts[2]) <= 8
    assert 8 <= np.mean(counts[10]) <= 20


def test_odin_near_optimal(db):
    """Fig. 9: ODIN configurations are close to the exhaustive search."""
    cfg0, _ = optimal_partition(db, [0] * 4, 4)
    ratios = []
    for ep in range(4):
        for scen in range(1, 13):
            s = [0] * 4
            s[ep] = scen
            src = SimTimeSource(db, s)
            res = odin_rebalance(cfg0, 10, src)
            _, t_opt = optimal_partition(db, s, 4)
            ratios.append(res.throughput / t_opt)
    assert np.mean(ratios) > 0.85
    assert min(ratios) > 0.6


@given(st.integers(2, 6), st.integers(1, 12), st.integers(0, 5),
       st.integers(1, 10))
@settings(max_examples=40, deadline=None)
def test_odin_invariants(n_eps, scen, ep_mod, alpha):
    """Layer conservation + returned throughput is best-seen (property)."""
    db = synthetic_database("resnet50", seed=1)
    cfg0 = balanced_config(db.num_layers, n_eps)
    scenarios = [0] * n_eps
    scenarios[ep_mod % n_eps] = scen
    src = SimTimeSource(db, scenarios)
    res = odin_rebalance(cfg0, alpha, src)
    assert sum(res.config) == db.num_layers
    assert all(c >= 0 for c in res.config)
    assert res.throughput == pytest.approx(
        throughput(src.stage_times(res.config)))
    # never worse than doing nothing (ODIN returns best-seen)
    assert res.throughput >= throughput(src.stage_times(cfg0)) - 1e-12
    # every trial conserves layers
    for tr in res.trials:
        assert sum(tr.config) == db.num_layers


# ---------------------------------------------------------------------------
# LLS baseline
# ---------------------------------------------------------------------------


def test_lls_never_degrades(db):
    cfg0, _ = optimal_partition(db, [0] * 4, 4)
    for scen_col in range(1, 13):
        s = [0, 0, scen_col, 0]
        src = SimTimeSource(db, s)
        res = lls_rebalance(cfg0, src)
        assert res.throughput >= throughput(src.stage_times(cfg0)) - 1e-12
        assert sum(res.config) == db.num_layers


def test_lls_short_phase(db):
    """Paper: ~1 serially processed query per LLS rebalancing phase."""
    cfg0, _ = optimal_partition(db, [0] * 4, 4)
    trials = []
    for ep in range(4):
        s = [0] * 4
        s[ep] = 6
        trials.append(lls_rebalance(cfg0, SimTimeSource(db, s)).num_trials)
    assert np.mean(trials) <= 8
