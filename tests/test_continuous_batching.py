"""Continuous batching + length-bucketed dispatch (docs/WORKLOADS.md).

Sim-side: chunked/scalar parity of the formed-dispatch paths, the
drain-vs-continuous queue-delay win on the benchmark's locked config,
closed-loop equivalence, occupancy/padded-token accounting (dense and
streaming), batch-aware exploration, and the seeded length samplers.
Live-side: a continuous serve smoke on the real JAX engine, the
closed pre-warmed compile-shape set, and `run_batch`'s typed
mixed-length error + single-query no-copy forwarding.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import simulate, synthetic_database
from repro.workloads import make_lengths
from repro.workloads.batching import (LengthBuckets, next_pow2,
                                      resolve_batching)

#: The benchmark row's locked configuration (benchmarks/runner_bench.py
#: `bench_batching`): bursty bimodal-length traffic against an 8-EP
#: vgg16 pipeline, where continuous joins monetize the bursts.
LOCKED = dict(
    scheduler="none", events=[], num_queries=800,
    workload="bursty",
    workload_kwargs=dict(rate=0.0035, burst_rate=0.007, burst_prob=0.05,
                         seed=7),
    max_batch=16, buckets="pow2:64:512",
    lengths="bimodal",
    lengths_kwargs=dict(short=48, long=420, p_long=0.1, seed=11),
    batch_overhead=30.0,
)


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


# ---------------------------------------------------------------------------
# chunked == scalar parity on the formed-dispatch paths


@pytest.mark.parametrize("scheduler,admission", [
    ("odin", None),
    ("lls", None),
    ("none", None),
    ("odin", "slo_shed"),
])
def test_continuous_chunked_scalar_identical(db, scheduler, admission):
    """Chunked and scalar continuous-batching runs make identical
    dispatch/join/shed decisions — full-array bit identity, including
    the paper's stress setting (freq=2, dur=100)."""
    kw = dict(scheduler=scheduler, num_queries=400, freq_period=2,
              duration=100, seed=0,
              workload="bursty",
              workload_kwargs=dict(rate=0.0035, burst_rate=0.007,
                                   burst_prob=0.05, seed=7),
              batching="continuous", max_batch=16, buckets="pow2:64:512",
              lengths="bimodal",
              lengths_kwargs=dict(short=48, long=420, p_long=0.1, seed=11),
              batch_overhead=30.0)
    if admission is not None:
        kw.update(admission=admission,
                  admission_kwargs=dict(slo=3000.0))
    a = simulate(db, 8, chunking=True, **kw)
    b = simulate(db, 8, chunking=False, **kw)
    for col in ("latencies", "queue_delays", "service_latencies",
                "batch_sizes", "arrival_times", "completion_times"):
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert a.configs_trace == b.configs_trace
    assert a.num_rebalances == b.num_rebalances
    assert a.total_trials == b.total_trials
    assert a.num_shed == b.num_shed
    if admission is not None:
        assert a.num_shed > 0, "slo_shed row should actually shed"


# ---------------------------------------------------------------------------
# the perf claim, on the benchmark's locked config


def test_continuous_beats_drain_on_locked_config(db):
    """Continuous >= 1.3x lower mean queue delay than drain at equal
    offered load, p99 no worse — the CI-gated benchmark row."""
    runs = {mode: simulate(db, 8, batching=mode, **LOCKED)
            for mode in ("drain", "continuous")}
    s = {mode: r.summary() for mode, r in runs.items()}
    ratio = (s["drain"]["mean_queue_delay_s"]
             / s["continuous"]["mean_queue_delay_s"])
    assert ratio >= 1.3
    assert (s["continuous"]["p99_queue_delay_s"]
            <= s["drain"]["p99_queue_delay_s"])
    # identical offered load and no losses: every query completes
    assert (s["drain"]["offered_load_qps"]
            == s["continuous"]["offered_load_qps"])
    for mode in runs:
        assert len(runs[mode].latencies) == LOCKED["num_queries"]


def test_closed_loop_drain_equals_continuous(db):
    """A closed loop serves one query at a time (the next arrival only
    exists once the previous completes), so there is nothing to join:
    both modes degenerate to the same solo-dispatch trace."""
    kw = dict(scheduler="odin", num_queries=300, freq_period=25,
              duration=10, seed=0, max_batch=16, buckets="pow2:64:512",
              lengths="bimodal",
              lengths_kwargs=dict(short=48, long=420, p_long=0.1, seed=11),
              batch_overhead=30.0)
    a = simulate(db, 8, batching="drain", **kw)
    b = simulate(db, 8, batching="continuous", **kw)
    assert np.array_equal(a.latencies, b.latencies)
    assert np.array_equal(a.batch_sizes, b.batch_sizes)
    assert a.summary()["mean_batch_occupancy"] == 1.0


def test_lengths_without_batching_is_accounting_only(db):
    """`lengths=` alone must not perturb dispatch: latencies are
    bit-identical to the plain run, and with no former there is no
    padding to account."""
    base = simulate(db, 8, scheduler="odin", num_queries=300, seed=0)
    lo = simulate(db, 8, scheduler="odin", num_queries=300, seed=0,
                  lengths="bimodal",
                  lengths_kwargs=dict(short=48, long=420, p_long=0.1,
                                      seed=11))
    assert np.array_equal(base.latencies, lo.latencies)
    assert lo.summary()["padded_token_frac"] == 0.0


# ---------------------------------------------------------------------------
# occupancy / padded-token accounting


def test_occupancy_and_padding_accounting(db):
    r = simulate(db, 8, batching="continuous", **LOCKED)
    s = r.summary()
    assert s["mean_batch_occupancy"] > 1.0, "bursts should form batches"
    assert 0.0 < s["padded_token_frac"] < 1.0
    assert r.batch_sizes.max() <= LOCKED["max_batch"]
    assert r.batch_sizes.min() >= 1.0


def test_streaming_trace_matches_dense_accounting(db):
    """trace_mode="streaming" reports the same summary key set and the
    identical occupancy/padding aggregates for a formed run."""
    dense = simulate(db, 8, batching="continuous", **LOCKED).summary()
    stream = simulate(db, 8, batching="continuous",
                      trace_mode="streaming", **LOCKED).summary()
    assert set(dense) == set(stream)
    assert stream["mean_batch_occupancy"] == pytest.approx(
        dense["mean_batch_occupancy"])
    assert stream["padded_token_frac"] == pytest.approx(
        dense["padded_token_frac"])


def test_explore_in_batch_keeps_exploring_with_riders(db):
    """Batch-aware exploration keeps the detect->explore->commit loop
    functional (trials run, rebalances land) while trial dispatches
    accept riders — occupancy no worse than serial-trial exploration."""
    kw = dict(scheduler="odin", num_queries=600, freq_period=50,
              duration=30, seed=0,
              workload="bursty",
              workload_kwargs=dict(rate=0.0035, burst_rate=0.007,
                                   burst_prob=0.05, seed=7),
              batching="continuous", max_batch=16, buckets="pow2:64:512",
              lengths="bimodal",
              lengths_kwargs=dict(short=48, long=420, p_long=0.1, seed=11),
              batch_overhead=30.0)
    serial = simulate(db, 8, **kw)
    riding = simulate(db, 8, explore_in_batch=True, **kw)
    for r in (serial, riding):
        assert r.num_rebalances >= 1
        assert r.total_trials > 0
        assert 0.0 < r.rebalance_fraction < 1.0
    assert (riding.summary()["mean_batch_occupancy"]
            >= serial.summary()["mean_batch_occupancy"])


# ---------------------------------------------------------------------------
# length buckets + formers (unit level)


def test_resolve_batching_modes():
    assert resolve_batching(None) is None
    drain = resolve_batching("drain", max_batch=4, buckets="pow2:64:256")
    cont = resolve_batching("continuous", max_batch=4,
                            buckets="pow2:64:256")
    assert not drain.continuous and cont.continuous
    assert drain.max_batch == cont.max_batch == 4
    with pytest.raises(ValueError, match="batching"):
        resolve_batching("sometimes")


def test_length_buckets_pow2_and_overflow():
    b = LengthBuckets.pow2(64, 512)
    assert list(b.edges) == [64, 128, 256, 512]
    assert b.pad(1) == 64
    assert b.pad(64) == 64
    assert b.pad(65) == 128
    assert b.pad(512) == 512
    with pytest.raises(ValueError):
        b.pad(513)
    padded = b.pad_many(np.array([48, 420, 64, 129]))
    assert list(padded) == [64, 512, 64, 256]
    with pytest.raises(ValueError):
        b.pad_many(np.array([48, 4096]))


def test_next_pow2():
    assert [next_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] \
        == [1, 2, 4, 4, 8, 8, 16]


# ---------------------------------------------------------------------------
# seeded length samplers


def test_length_samplers_seeded_deterministic():
    for name, kw in (("uniform", dict(lo=32, hi=128, seed=3)),
                     ("bimodal", dict(short=48, long=420, p_long=0.1,
                                      seed=3))):
        a = make_lengths(name, **kw).sample(500)
        b = make_lengths(name, **kw).sample(500)
        assert np.array_equal(a, b), name
        c = make_lengths(name, **{**kw, "seed": 4}).sample(500)
        assert not np.array_equal(a, c), name


def test_length_sampler_bounds_and_support():
    u = make_lengths("uniform", lo=32, hi=128, seed=0).sample(1000)
    assert u.min() >= 32 and u.max() <= 128
    bi = make_lengths("bimodal", short=48, long=420, p_long=0.25,
                      seed=0).sample(1000)
    assert set(np.unique(bi)) == {48, 420}
    frac_long = float(np.mean(bi == 420))
    assert 0.15 < frac_long < 0.35
    f = make_lengths("fixed", length=96).sample(10)
    assert np.array_equal(f, np.full(10, 96))


def test_trace_lengths_replay_and_cycle():
    t = make_lengths("trace", lengths=[64, 128, 256])
    assert list(t.sample(3)) == [64, 128, 256]
    assert list(t.sample(7)) == [64, 128, 256, 64, 128, 256, 64]
    with pytest.raises(ValueError):
        make_lengths("no_such_sampler")


# ---------------------------------------------------------------------------
# adaptive_batch occupancy feedback


def test_adaptive_batch_occupancy_accelerates_widening():
    """With the p99 comfortably under the SLO, a bound whose dispatches
    run near-full widens x4; a mostly-idle bound widens x2."""
    from repro.control import make_admission

    def feed(occupancy):
        adm = make_admission("adaptive_batch", slo=10.0, min_batch=1,
                             max_batch=64, interval=8)
        adm._bound = 4
        for _ in range(8):
            adm.observe(0.001, 0.5, occupancy=occupancy)
        return adm._bound

    assert feed(4.0) == 16      # saturated: 4 -> x4
    assert feed(1.0) == 8       # idle dispatches: 4 -> x2


def test_adaptive_batch_occupancy_default_backward_compatible():
    """observe() without the occupancy kwarg still works (the sim's
    vector mode reports occupancy 1.0) and shrink stays occupancy-blind."""
    from repro.control import make_admission
    adm = make_admission("adaptive_batch", slo=1.0, min_batch=1,
                         max_batch=64, interval=4)
    adm._bound = 16
    for _ in range(4):
        adm.observe(5.0, 0.5, occupancy=16.0)   # p99 blown: halve anyway
    assert adm._bound == 8
    adm2 = make_admission("adaptive_batch", slo=10.0, min_batch=1,
                          max_batch=64, interval=4)
    adm2._bound = 4
    for _ in range(4):
        adm2.observe(0.001, 0.5)                # legacy call signature
    assert adm2._bound == 8


# ---------------------------------------------------------------------------
# live engine: continuous serving on the real JAX pipeline


@pytest.fixture(scope="module")
def live_setup():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=8)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size,
                                        (1, 64 if rng.random() < 0.3
                                         else 32)))
               for _ in range(24)]
    return cfg, params, queries


def _live_schedule(q):
    slow = [1.0] * 4
    if 6 <= q < 16:
        slow[1] = 2.0
    return slow


_LIVE_KW = dict(workload="bursty",
                workload_kwargs=dict(rate=30.0, burst_rate=300.0,
                                     burst_prob=0.2, seed=1),
                batching="continuous", max_batch=4, buckets="pow2:32:64")


def test_live_continuous_serve_smoke(live_setup):
    from repro.serving import ServingEngine

    cfg, params, queries = live_setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="odin", alpha=3)
    m = eng.serve(queries, _live_schedule, **_LIVE_KW)
    s = m.summary()
    assert len(m.latencies) == len(queries)
    assert s["mean_batch_occupancy"] >= 1.0
    # sim/live summary parity holds for formed-dispatch runs too
    sim_s = simulate(synthetic_database("vgg16", seed=0), 8,
                     batching="continuous", **LOCKED).summary()
    assert set(s) == set(sim_s)
    assert np.all(m.queue_delays >= 0)
    assert np.all(m.service_latencies > 0)
    # the compiled-shape set is the closed pow2-rows x bucket-edges
    # family — nothing outside it may have been warmed
    edges = (32, 64)
    for rows, seq in eng.executor._warmed:
        assert seq in edges and rows == next_pow2(rows)

    # regression: a fresh serve over warm shapes must not compile —
    # any ensure_warm cache miss would call warmup and raise here
    def no_compiles(*a, **k):
        raise AssertionError(f"compile requested in warm serve: {a}")

    eng.reset_policy()
    eng.executor.warmup = no_compiles
    m2 = eng.serve(queries, _live_schedule, **_LIVE_KW)
    assert len(m2.latencies) == len(queries)


def test_run_batch_typed_error_and_no_copy(live_setup):
    from repro.pipeline.executor import (LocalPipelineExecutor,
                                         MixedSequenceLengthError)

    cfg, params, queries = live_setup
    ex = LocalPipelineExecutor(cfg, params)
    config = [2, 2, 2, 2]

    q32 = next(q for q in queries if q.shape[-1] == 32)
    q64 = next(q for q in queries if q.shape[-1] == 64)
    with pytest.raises(MixedSequenceLengthError) as ei:
        ex.run_batch([q32, q64, q32], config)
    assert ei.value.lengths == [32, 64, 32]
    assert "32" in str(ei.value) and "64" in str(ei.value)
    assert isinstance(ei.value, ValueError)   # legacy except clauses

    # single-query dispatch forwards the tokens object untouched
    seen = {}
    orig = ex.run_query

    def spy(tokens, config, slowdowns=None):
        seen["tokens"] = tokens
        return orig(tokens, config, slowdowns=slowdowns)

    ex.run_query = spy
    try:
        logits, stage_times = ex.run_batch([q32], config)
    finally:
        ex.run_query = orig
    assert seen["tokens"] is q32
    assert logits.shape[0] == 1 and stage_times.shape == (4,)
