"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency; on a clean checkout without it
the suite must still collect and run (the example-based tests are the
tier-1 gate).  Importing ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` keeps property tests active when hypothesis is
installed and turns them into skips — not collection errors — when it
is not.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    class _NullStrategies:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _NullStrategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
