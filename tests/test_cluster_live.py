"""Live-engine cluster backend: real JAX replicas behind a router.

Measured wall-clock times on a shared CI host are noisy (scheduler
stalls of 100ms+ on 5ms queries), so the router comparison aggregates
best-of-3 runs per router — the same noise-suppression rule
``benchmarks/runner_bench.py`` uses — and asserts with margins the
structural effects comfortably clear.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import serve_cluster
from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=8)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)))
               for _ in range(72)]
    # One jitted executor serves the whole fleet (replicas run the same
    # model); each engine keeps its own runtime/detector/estimates.
    engines = [ServingEngine(cfg, params, num_eps=4, scheduler="odin",
                             alpha=3, estimate_beta=0.3)]
    engines[0].executor.warmup(1, 64)
    for _ in range(3):
        engines.append(ServingEngine(cfg, params, num_eps=4,
                                     scheduler="odin", alpha=3,
                                     estimate_beta=0.3,
                                     executor=engines[0].executor))
    # calibrate the arrival rate to this host's measured service time
    probe = engines[0].serve(queries[:6], lambda q: [1.0] * 4)
    service = float(probe.service_latencies[2:].mean())
    engines[0].reset_policy()
    return cfg, engines, queries, service


def _interfered_schedules(num_eps=4, victim=2, factor=12.0):
    """Replica-scoped interference: only the victim replica's EP 1 is
    slowed, for (almost) the whole run."""
    def make(r):
        def sched(q):
            slow = [1.0] * num_eps
            if r == victim and q >= 1:
                slow[1] = factor
            return slow
        return sched
    return [make(r) for r in range(num_eps)]


def test_live_cluster_basic_closed_loop(setup):
    """Two live replicas, closed loop: every query is served exactly
    once, per-replica accounting adds up, peaks get stamped."""
    cfg, engines, queries, service = setup
    for e in engines[:2]:
        e.reset_policy()
    ct = serve_cluster(engines[:2], queries[:16],
                       lambda q: [1.0] * 4, router="round_robin")
    assert ct.num_queries == 16
    assert np.array_equal(ct.replica_counts, [8, 8])
    assert np.all(ct.fleet.service_latencies > 0)
    assert all(np.isfinite(t.peak_throughput) for t in ct.replicas)
    for t in ct.replicas:
        for c in t.configs_trace:
            assert sum(c) == cfg.num_blocks
    s = ct.summary()
    assert s["num_replicas"] == 2
    assert 0.0 <= s["slo_violations"] <= 1.0


def test_live_odin_aware_beats_round_robin_under_replica_interference(
        setup):
    """The acceptance scenario on the live backend: one of 4 replicas
    physically interfered (12x on one EP — unstable under a 1/4 share),
    poisson arrivals at ~0.6 of clean fleet capacity.  odin_aware must
    sustain better fleet p99 and throughput than round_robin and stay
    in least_outstanding's band (best-of-3 per router)."""
    cfg, engines, queries, service = setup
    schedules = _interfered_schedules()
    wl = dict(rate=2.4 / service, seed=7)
    routers = ("round_robin", "least_outstanding", "odin_aware")
    p99s = {r: [] for r in routers}
    thrs = {r: [] for r in routers}
    shares = {}
    best_p99, best_thr = {}, {}
    # Best-of-N with escalation: host stalls occasionally eat a whole
    # 3-trial round, so keep adding rounds (up to 3) until the
    # structural margins clear the noise; the final round's values are
    # what the asserts below see.
    for _ in range(3):
        for router in routers:
            for _ in range(3):
                for e in engines:
                    e.reset_policy()
                ct = serve_cluster(engines, queries, schedules,
                                   workload="poisson",
                                   workload_kwargs=wl, router=router)
                s = ct.summary()
                p99s[router].append(s["p99_latency_s"])
                thrs[router].append(s["achieved_load_qps"])
            shares[router] = ct.replica_counts
        best_p99 = {r: min(v) for r, v in p99s.items()}
        best_thr = {r: max(v) for r, v in thrs.items()}
        if (best_p99["odin_aware"] < best_p99["round_robin"]
                and best_p99["odin_aware"]
                <= 1.4 * best_p99["least_outstanding"]
                and best_thr["odin_aware"] > best_thr["round_robin"]
                and best_thr["odin_aware"]
                >= 0.8 * best_thr["least_outstanding"]):
            break
    # p99: strictly better than round robin; within least_outstanding's
    # band (the 1.4x headroom absorbs host jitter, not the effect —
    # observed ratios are ~0.2-0.9)
    assert best_p99["odin_aware"] < best_p99["round_robin"]
    assert best_p99["odin_aware"] <= 1.4 * best_p99["least_outstanding"]
    # throughput: strictly better than round robin (RR burns a 1/4
    # share on the degraded replica), no worse than least_outstanding
    assert best_thr["odin_aware"] > best_thr["round_robin"]
    assert best_thr["odin_aware"] >= 0.8 * best_thr["least_outstanding"]
    # the mechanism: round robin force-feeds the victim its full share,
    # the aware router routes away
    assert shares["round_robin"][2] == len(queries) // 4
    assert shares["odin_aware"][2] < shares["round_robin"][2]
