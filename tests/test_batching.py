"""Batch-granular fast path: chunked == scalar, pruned ledger, vectorized
helpers (docs/WORKLOADS.md "Batching & the fast path")."""
import bisect
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (
    EventTimeline,
    InterferenceEvent,
    generate_events,
    simulate,
    synthetic_database,
)
from repro.core.simulator import DatabaseQueryExecutor
from repro.pipeline.executor import MeasuredTimeSource
from repro.schedulers import RebalanceRuntime, make_scheduler
from repro.serving.engine import ServingEngine
from repro.workloads import BatchRecord, run_pipeline
from repro.workloads.runner import _CompletionLedger


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


def _trace_fields(r):
    return (r.latencies, r.throughputs, r.service_latencies, r.queue_delays,
            r.arrival_times, r.completion_times, r.rc_throughputs)


# ---------------------------------------------------------------------------
# chunked == scalar: closed loop bit-identical, open loop within tolerance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheduler", ["odin", "lls", "hybrid", "none",
                                       "oracle"])
@pytest.mark.parametrize("freq,dur", [(10, 10), (2, 100), (100, 10)])
def test_closed_loop_chunked_bit_identical(db, scheduler, freq, dur):
    """The fast path must change nothing: full per-query arrays,
    configs, accounting, and queue depths — bit for bit — including the
    paper's heavy-overlap setting (freq=2, dur=100) where rebalances
    constantly interleave with the steady chunks."""
    kw = dict(num_queries=500, freq_period=freq, duration=dur, seed=3)
    if scheduler != "oracle":
        kw["alpha"] = 4
    a = simulate(db, 4, scheduler=scheduler, chunking=False, **kw)
    b = simulate(db, 4, scheduler=scheduler, chunking=True, **kw)
    for x, y in zip(_trace_fields(a), _trace_fields(b)):
        assert np.array_equal(x, y)
    assert np.array_equal(a.serial_mask, b.serial_mask)
    assert np.array_equal(a.queue_depths, b.queue_depths)
    assert a.configs_trace == b.configs_trace
    assert a.num_rebalances == b.num_rebalances
    assert a.total_trials == b.total_trials
    assert a.mitigation_lengths == b.mitigation_lengths


@pytest.mark.parametrize("workload,wl_kwargs", [
    ("poisson", dict(rate=0.012, seed=7)),
    ("bursty", dict(burst_rate=0.03, base_rate=0.001,
                    mean_burst=2000, mean_gap=4000, seed=3)),
])
@pytest.mark.parametrize("scheduler", ["odin", "none"])
def test_open_loop_chunked_within_tolerance(db, workload, wl_kwargs,
                                            scheduler):
    """Open-loop chunks use the max-plus closed form, exact up to float
    re-association: identical accounting and integer depths, per-query
    times within 1e-9 relative."""
    kw = dict(num_queries=500, freq_period=20, duration=10, seed=1,
              workload=workload, workload_kwargs=wl_kwargs)
    a = simulate(db, 4, scheduler=scheduler, chunking=False, **kw)
    b = simulate(db, 4, scheduler=scheduler, chunking=True, **kw)
    for x, y in zip(_trace_fields(a), _trace_fields(b)):
        assert np.allclose(x, y, rtol=1e-9, atol=0.0)
    assert np.array_equal(a.serial_mask, b.serial_mask)
    assert np.array_equal(a.queue_depths, b.queue_depths)
    assert a.configs_trace == b.configs_trace
    assert a.num_rebalances == b.num_rebalances
    assert a.total_trials == b.total_trials
    # rebalances landing mid-chunk: the runs above must actually explore
    if scheduler == "odin":
        assert a.num_rebalances > 0


def test_chunk_cap_still_bit_identical(db):
    """A tiny max_chunk splits every segment into many chunks; results
    must not depend on where the chunk boundaries fall."""
    kw = dict(num_queries=400, freq_period=50, duration=25, seed=5,
              scheduler="odin", alpha=4)
    a = simulate(db, 4, chunking=False, **kw)
    b = simulate(db, 4, chunking=True, max_chunk=7, **kw)
    assert np.array_equal(a.latencies, b.latencies)
    assert np.array_equal(a.queue_depths, b.queue_depths)
    assert a.configs_trace == b.configs_trace


# ---------------------------------------------------------------------------
# satellite: the pruned completion ledger (bisect.insort replacement)
# ---------------------------------------------------------------------------


def test_queue_depth_matches_unpruned_bisect_reference(db):
    """Regression for the pruned heap: depth accounting is unchanged
    from the old never-pruned ``bisect.insort`` ledger."""
    r = simulate(db, 4, scheduler="odin", alpha=4, num_queries=400,
                 freq_period=20, duration=10, seed=1, workload="poisson",
                 workload_kwargs=dict(rate=0.02, seed=7))
    assert r.queue_depths.max() > 4     # overloaded: the queue does grow
    pending = []                        # the old unpruned ledger, verbatim
    for q in range(len(r.latencies)):
        arrival = r.arrival_times[q]
        depth = len(pending) - bisect.bisect_right(pending, arrival)
        assert r.queue_depths[q] == depth, f"depth diverged at q={q}"
        bisect.insort(pending, r.completion_times[q])


def test_completion_ledger_prunes_to_in_system_depth():
    led = _CompletionLedger()
    for t in (5.0, 3.0, 9.0, 7.0):
        led.push(t)
    assert led.depth_at(0.0) == 4
    assert led.depth_at(4.0) == 3       # 3.0 pruned
    assert len(led._heap) == 3          # flat memory: pruned, not kept
    assert led.depth_at(9.0) == 0       # <= arrival never counts
    assert len(led._heap) == 0


def test_completion_ledger_bulk_matches_scalar():
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.uniform(0.0, 2.0, 64))
    completions = arrivals + 3.0        # monotone, overlapping
    scalar = _CompletionLedger()
    prior = [1.0, 2.5, 40.0, 41.0]
    for t in prior:
        scalar.push(t)
    expect = []
    for a, c in zip(arrivals, completions):
        expect.append(scalar.depth_at(a))
        scalar.push(c)
    bulk = _CompletionLedger()
    for t in prior:
        bulk.push(t)
    got = bulk.depths_bulk(arrivals, completions)
    assert np.array_equal(got, np.asarray(expect))
    # both ledgers answer the next arrival identically afterwards
    assert bulk.depth_at(arrivals[-1] + 1.0) == \
        scalar.depth_at(arrivals[-1] + 1.0)


def test_completion_ledger_rejects_decreasing_completions():
    led = _CompletionLedger()
    with pytest.raises(ValueError, match="non-decreasing"):
        led.depths_bulk(np.array([1.0, 2.0]), np.array([5.0, 3.0]))


# ---------------------------------------------------------------------------
# satellite: vectorized MeasuredTimeSource / block-estimate updates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("config", [
    [4, 4, 4, 4], [0, 8, 0, 8], [16, 0, 0, 0], [1, 0, 15, 0],
    [0, 0, 0, 16], [2, 5, 6, 3],
])
def test_measured_time_source_reduceat_matches_loop(config):
    rng = np.random.default_rng(1)
    block_times = rng.uniform(0.5, 2.0, 16)
    slowdowns = np.array([1.0, 2.5, 1.0, 3.0])
    got = MeasuredTimeSource(block_times, slowdowns).stage_times(config)
    ref = np.zeros(len(config))
    lo = 0
    for i, c in enumerate(config):
        ref[i] = block_times[lo:lo + c].sum() * slowdowns[i]
        lo += c
    assert np.allclose(got, ref, rtol=1e-12)
    assert got[np.asarray(config) == 0].sum() == 0.0


@pytest.mark.parametrize("config", [[4, 4, 4, 4], [7, 0, 8, 1],
                                    [16, 0, 0, 0]])
def test_update_block_estimates_matches_loop_reference(config):
    rng = np.random.default_rng(2)
    old = rng.uniform(1e-3, 2e-3, 16)
    stage_times = rng.uniform(0.01, 0.05, 4)
    slowdowns = np.array([1.0, 3.0, 1.0, 2.0])
    eng = SimpleNamespace(_block_times=old.copy(), estimate_beta=0.5,
                          cfg=SimpleNamespace(num_blocks=16))
    ServingEngine._update_block_estimates(eng, config, stage_times,
                                          slowdowns)
    ref = old.copy()
    lo = 0
    for s, c in enumerate(config):       # the old scalar loop, verbatim
        if c > 0:
            per_block = stage_times[s] / max(slowdowns[s], 1e-9) / c
            ref[lo:lo + c] = 0.5 * ref[lo:lo + c] + 0.5 * per_block
        lo += c
    assert np.array_equal(eng._block_times, ref)


def test_update_block_estimates_first_measurement_seeds_directly():
    eng = SimpleNamespace(_block_times=None, estimate_beta=0.5,
                          cfg=SimpleNamespace(num_blocks=4))
    ServingEngine._update_block_estimates(eng, [2, 2], [0.4, 0.8],
                                          [1.0, 2.0])
    assert np.allclose(eng._block_times, [0.2, 0.2, 0.2, 0.2])


# ---------------------------------------------------------------------------
# EventTimeline.next_change: the chunk boundary oracle
# ---------------------------------------------------------------------------


def test_event_timeline_next_change_brackets_constant_segments():
    events = [InterferenceEvent(start=10, duration=5, ep=0, scenario=2),
              InterferenceEvent(start=12, duration=10, ep=1, scenario=1),
              InterferenceEvent(start=30, duration=3, ep=0, scenario=3)]
    tl = EventTimeline(events, num_eps=2)
    q = 0
    while q < 40:
        nxt = min(tl.next_change(q), 40)
        scen = tl.scenarios_at(q)
        for j in range(q, nxt):
            assert tl.scenarios_at(j) == scen, (q, j)
        q = nxt
    assert tl.next_change(33) > 10 ** 12     # no further edges: sentinel


def test_event_timeline_next_change_matches_generated_events(db):
    events = generate_events(300, 4, db.num_scenarios, 10, 25, seed=9)
    tl = EventTimeline(events, 4, severity=db.scenario_severities())
    edges = sorted({b for ev in events for b in (ev.start, ev.end)})
    for q in (0, 5, 10, 99, 150, 299):
        expect = next((b for b in edges if b > q), None)
        got = tl.next_change(q)
        if expect is None:
            assert got > 10 ** 12
        else:
            assert got == expect


def test_database_executor_steady_horizon(db):
    events = [InterferenceEvent(start=20, duration=10, ep=1, scenario=4)]
    ex = DatabaseQueryExecutor(db, 4, events, lambda scen: ([4, 4, 4, 4],
                                                            1.0))
    assert ex.steady_horizon(0) == 20
    assert ex.steady_horizon(19) == 1
    assert ex.steady_horizon(20) == 10
    assert ex.steady_horizon(25) == 5


# ---------------------------------------------------------------------------
# the executor protocol: custom executors + malformed batches
# ---------------------------------------------------------------------------


class _ConstExecutor:
    """Minimal vector-mode executor: constant stage time everywhere."""

    batch_mode = "vector"

    def __init__(self, bad_length=False):
        self.bad_length = bad_length
        self.chunks = []

    def begin_query(self, q):
        return self                      # its own StageTimeSource

    def stage_times(self, config):
        return np.ones(len(config))

    def steady_horizon(self, q):
        return 10 ** 9

    def execute(self, q, step):
        from repro.workloads import QueryRecord
        return QueryRecord(service_latency=2.0, throughput=1.0)

    def execute_many(self, q0, steps):
        n = len(steps)
        self.chunks.append(n)
        m = n - 1 if self.bad_length and n > 1 else n
        return BatchRecord(service_latencies=np.full(m, 2.0),
                           throughputs=np.ones(m))


def test_custom_vector_executor_chunks_and_matches_scalar():
    ex = _ConstExecutor()
    rt = RebalanceRuntime(make_scheduler("none"), [2, 2])
    r = run_pipeline(ex, rt, 50, workload="closed")
    assert ex.chunks and max(ex.chunks) > 1      # the fast path engaged
    rt2 = RebalanceRuntime(make_scheduler("none"), [2, 2])
    r2 = run_pipeline(_ConstExecutor(), rt2, 50, workload="closed",
                      chunking=False)
    assert np.array_equal(r.latencies, r2.latencies)
    assert np.array_equal(r.arrival_times, r2.arrival_times)


def test_run_pipeline_rejects_wrong_length_batchrecord():
    ex = _ConstExecutor(bad_length=True)
    rt = RebalanceRuntime(make_scheduler("none"), [2, 2])
    with pytest.raises(ValueError, match="records for a chunk"):
        run_pipeline(ex, rt, 50, workload="closed")


def test_batchrecord_rejects_misaligned_arrays():
    with pytest.raises(ValueError, match="index-aligned"):
        BatchRecord(service_latencies=np.ones(3), throughputs=np.ones(2))


def test_stateful_detector_policies_keep_per_query_polling(db):
    """A policy without ``steady_detect_stable`` (here: the engine's
    EMA detector mode) must be polled every query — the vector fast
    path still runs, via per-query-poll accumulation, and matches the
    scalar path exactly (EMA state sees the same observations)."""
    sched = make_scheduler("odin", alpha=4, detector="ema")
    assert not sched.steady_detect_stable
    kw = dict(num_queries=300, freq_period=25, duration=10, seed=2)
    a = simulate(db, 4, scheduler=sched, chunking=False, **kw)
    sched2 = make_scheduler("odin", alpha=4, detector="ema")
    b = simulate(db, 4, scheduler=sched2, chunking=True, **kw)
    assert np.array_equal(a.latencies, b.latencies)
    assert a.configs_trace == b.configs_trace
    assert a.num_rebalances == b.num_rebalances
    assert a.total_trials == b.total_trials
