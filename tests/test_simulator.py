"""Simulator behaviour + paper-claim sanity checks (fast settings)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import generate_events, simulate, synthetic_database


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


def test_events_schedule():
    evs = generate_events(1000, 4, 12, freq_period=100, duration=50, seed=0)
    assert len(evs) == 9
    assert all(1 <= e.scenario <= 12 for e in evs)
    assert all(0 <= e.ep < 4 for e in evs)


def test_no_interference_no_rebalance(db):
    r = simulate(db, 4, scheduler="odin", num_queries=200,
                 events=[])
    assert r.num_rebalances == 0
    assert np.all(r.throughputs == r.throughputs[0])
    assert r.throughputs[0] == pytest.approx(r.peak_throughput)


def test_odin_beats_static_under_sustained_interference(db):
    kw = dict(num_queries=1500, freq_period=100, duration=100, seed=3)
    r_odin = simulate(db, 4, scheduler="odin", alpha=10, **kw)
    r_none = simulate(db, 4, scheduler="none", **kw)
    assert r_odin.throughputs.mean() > r_none.throughputs.mean()
    assert r_odin.num_rebalances > 0


def test_oracle_upper_bounds_odin(db):
    kw = dict(num_queries=800, freq_period=50, duration=50, seed=5)
    r_odin = simulate(db, 4, scheduler="odin", alpha=10, **kw)
    r_orc = simulate(db, 4, scheduler="oracle", **kw)
    assert r_orc.throughputs.mean() >= r_odin.throughputs.mean() * 0.98


def test_slo_violation_monotone_in_level(db):
    r = simulate(db, 4, scheduler="odin", alpha=10, num_queries=800,
                 freq_period=20, duration=20, seed=7)
    v = [r.slo_violations(level) for level in (0.9, 0.7, 0.5, 0.3)]
    assert all(a >= b - 1e-12 for a, b in zip(v, v[1:]))


def test_serial_fraction_increases_with_frequency(db):
    r_fast = simulate(db, 4, scheduler="odin", alpha=10, num_queries=1000,
                      freq_period=2, duration=2, seed=1)
    r_slow = simulate(db, 4, scheduler="odin", alpha=10, num_queries=1000,
                      freq_period=100, duration=2, seed=1)
    assert r_fast.rebalance_fraction > r_slow.rebalance_fraction


def test_mitigation_phase_length_matches_paper(db):
    """Mitigation takes 5-15 timesteps (paper abstract / §4.2)."""
    r = simulate(db, 4, scheduler="odin", alpha=10, num_queries=2000,
                 freq_period=100, duration=100, seed=2)
    assert r.mitigation_lengths, "no rebalancing happened"
    assert 5 <= np.mean(r.mitigation_lengths) <= 20


@given(st.sampled_from(["odin", "lls", "none"]),
       st.integers(2, 5), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_simulator_properties(sched, n_eps, seed):
    db = synthetic_database("resnet50", seed=7)
    r = simulate(db, n_eps, scheduler=sched, alpha=4, num_queries=300,
                 freq_period=25, duration=25, seed=seed)
    assert r.latencies.shape == (300,)
    assert np.all(r.latencies > 0)
    assert np.all(r.throughputs > 0)
    # every trace config conserves layers
    for c in r.configs_trace:
        assert sum(c) == db.num_layers
    # resource-constrained oracle bounds observed throughput
    assert np.all(r.throughputs <= r.rc_throughputs + 1e-9)
