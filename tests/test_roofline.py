"""Roofline machinery: HLO collective parser (loop-aware) + terms."""
import pytest

from repro.launch.roofline import (
    RooflineTerms,
    _shape_bytes,
    _trip_count,
    collective_bytes,
)

HLO_FLAT = """
HloModule test

ENTRY %main.1 (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  %ag = f32[32,8]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[8,8]{1,0} all-reduce(%p0), to_apply=%add
  ROOT %out = f32[8,8] add(%p0, %p0)
}
"""

HLO_LOOP = """
HloModule test

%cond.1 (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(6)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body.1 (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%arg), index=1
  %ag = f32[32,8]{1,0} all-gather(%x), dimensions={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%iv, %x)
}

ENTRY %main.2 (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32", "8,8") == 256
    assert _shape_bytes("bf16", "4,2,2") == 32
    assert _shape_bytes("f32", "") == 4


def test_flat_collectives():
    cb = collective_bytes(HLO_FLAT)
    assert cb["all-gather"] == 32 * 8 * 4
    assert cb["all-reduce"] == 8 * 8 * 4


def test_loop_aware_collectives():
    cb = collective_bytes(HLO_LOOP)
    # all-gather inside a 6-trip while loop counts 6x
    assert cb["all-gather"] == 6 * 32 * 8 * 4


def test_trip_count_parse():
    assert _trip_count("%c = s32[] constant(24)\ncompare") == 24
    assert _trip_count("no constants") == 1


def test_terms_bottleneck():
    t = RooflineTerms(
        flops=197e12 * 256,          # exactly 1s of compute on 256 chips
        bytes_accessed=819e9,        # ~0.004s memory
        hlo_flops=0, hlo_bytes=0,
        coll_bytes=50e9 * 3,         # 3s of collectives
        coll_breakdown={}, chips=256, model_flops=197e12 * 128,
    ).finalize()
    assert t.t_compute == pytest.approx(1.0)
    assert t.bottleneck == "collective"
    assert t.useful_ratio == pytest.approx(0.5)
