"""Mesh-sliced stage execution (docs/SHARDING.md) + the unified RunSpec
API (docs/API.md): no-mesh bit-identity, chunked==scalar under mesh
events, the (boundary, slice) oracle beating boundary-only, sim/live
summary-key parity, and spec-path == kwarg-path equivalence."""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (
    AdmissionSpec,
    BatchingSpec,
    ClusterSpec,
    MeshSpec,
    RunSpec,
    SchedulerSpec,
    WorkloadSpec,
    run,
)
from repro.cluster.sim import _simulate_cluster_impl, simulate_cluster
from repro.core import InterferenceEvent, generate_events, simulate
from repro.core.database import synthetic_database
from repro.core.exhaustive import optimal_partition, optimal_partition_mesh
from repro.core.mesh import (
    balanced_assignment,
    collective_frac,
    mesh_stage_times,
    resolve_mesh,
    ring_factor,
)
from repro.core.simulator import _simulate_impl

NUM_EPS = 4

#: A mesh whose collective costs actually bite: per-layer collective
#: time on the order of per-layer compute, so slice moves matter.
HEAVY_MESH = MeshSpec(devices=8, coll_cost=0.5)


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


def mesh_events(num_queries, factor=6.0, seed=3):
    """Interference events plus one mesh-contention episode mid-run."""
    evs = list(generate_events(num_queries, NUM_EPS, 12, 20, 10,
                               seed=seed))
    evs.append(InterferenceEvent(start=num_queries // 3,
                                 duration=num_queries // 4, ep=0,
                                 scenario=0, kind="mesh", factor=factor))
    return evs


def _same_trace(a, b):
    assert np.array_equal(a.latencies, b.latencies)
    assert np.array_equal(a.throughputs, b.throughputs)
    assert a.configs_trace == b.configs_trace
    assert a.num_rebalances == b.num_rebalances
    sa, sb = a.summary(), b.summary()
    assert sa.keys() == sb.keys()
    for k in sa:
        assert sa[k] == sb[k] or (sa[k] != sa[k] and sb[k] != sb[k]), k


# ---------------------------------------------------------------------------
# no-mesh bit-identity
# ---------------------------------------------------------------------------


def test_no_mesh_single_pipeline_is_unsharded_and_deterministic(db):
    """The public simulate() never arms a mesh: no mesh trace surface,
    no mesh summary keys, and reruns are bit-identical."""
    a = simulate(db, NUM_EPS, scheduler="odin", num_queries=400)
    b = simulate(db, NUM_EPS, scheduler="odin", num_queries=400)
    assert a.mesh_devices == 0 and a.mesh_trace is None
    assert a.collective_fracs is None and a.num_mesh_resizes == 0
    assert not any("mesh" in k or "collective" in k for k in a.summary())
    _same_trace(a, b)


def test_no_mesh_impl_none_matches_public_wrapper(db):
    """mesh=None on the impl is the public wrapper's exact path."""
    events = list(generate_events(400, NUM_EPS, db.num_scenarios, 20,
                                  10, seed=3))
    a = simulate(db, NUM_EPS, scheduler="odin", num_queries=400,
                 events=list(events))
    b = _simulate_impl(db, NUM_EPS, scheduler="odin", num_queries=400,
                       events=list(events), mesh=None)
    _same_trace(a, b)


def test_no_mesh_cluster_is_unsharded_and_deterministic(db):
    a = simulate_cluster(db, NUM_EPS, 2, scheduler="odin",
                         num_queries=300)
    b = simulate_cluster(db, NUM_EPS, 2, scheduler="odin",
                         num_queries=300)
    for rep in a.replicas:
        assert rep.mesh_devices == 0 and rep.mesh_trace is None
    assert not any("mesh" in k or "collective" in k for k in a.summary())
    assert np.array_equal(a.fleet.latencies, b.fleet.latencies)
    assert np.array_equal(a.assignments, b.assignments)


# ---------------------------------------------------------------------------
# mesh-armed simulation: trace surface + chunked == scalar
# ---------------------------------------------------------------------------


def test_mesh_armed_trace_surface(db):
    t = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=400,
                    events=mesh_events(400), mesh=HEAVY_MESH))
    assert t.mesh_devices == 8
    assert t.mesh_trace is not None and len(t.mesh_trace) == len(t.configs)
    assert all(sum(a) == 8 and all(m >= 1 for m in a)
               for a in t.mesh_trace)
    assert t.collective_fracs is not None
    assert float(np.max(t.collective_fracs)) > 0.0
    s = t.summary()
    assert s["mesh_devices"] == 8.0
    assert {"num_mesh_resizes", "mean_collective_frac",
            "p99_collective_frac"} <= s.keys()


def test_mesh_chunked_equals_scalar(db):
    """The chunked fast path must cut on mesh edges exactly like the
    scalar tick — bit-identical traces with mesh events in play."""
    kw = dict(db=db, num_eps=NUM_EPS, num_queries=400,
              events=mesh_events(400), mesh=HEAVY_MESH,
              scheduler=SchedulerSpec(name="odin"))
    fast = run(RunSpec(**kw))
    slow = run(RunSpec(**kw, batching=BatchingSpec(chunking=False)))
    _same_trace(fast, slow)
    assert np.array_equal(fast.collective_fracs, slow.collective_fracs)
    assert fast.mesh_trace == slow.mesh_trace


def test_mesh_event_inflates_collective_time(db):
    """A kind="mesh" event slows sharded stages (collective term scales
    by `factor`) but leaves an unsharded run untouched."""
    ev = [InterferenceEvent(start=100, duration=100, ep=0, scenario=0,
                            kind="mesh", factor=8.0)]
    quiet = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=300,
                        events=(), mesh=HEAVY_MESH,
                        scheduler=SchedulerSpec(name="none")))
    noisy = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=300,
                        events=ev, mesh=HEAVY_MESH,
                        scheduler=SchedulerSpec(name="none")))
    assert noisy.latencies[100:200].mean() > quiet.latencies[100:200].mean()
    # mesh events are invisible without a mesh
    base = simulate(db, NUM_EPS, scheduler="none", num_queries=300,
                    events=[])
    noisy_nomesh = simulate(db, NUM_EPS, scheduler="none",
                            num_queries=300, events=list(ev))
    _same_trace(base, noisy_nomesh)


# ---------------------------------------------------------------------------
# the (boundary, slice) oracle
# ---------------------------------------------------------------------------


def test_cost_model_shape_and_ring_factor():
    compute = np.array([1.0, 1.0, 2.0, 4.0])
    config = [1, 1, 1, 1]
    mesh = MeshSpec(devices=8, coll_cost=0.25)
    t_bal = mesh_stage_times(compute, config, [1, 1, 2, 4], mesh, 1.0)
    # compute/m + coll*ring(m): slicing the heavy stages evens them out
    assert ring_factor(1) == 0.0 and ring_factor(4) == 0.75
    assert t_bal[3] == pytest.approx(4.0 / 4 + 0.25 * 0.75)
    frac = collective_frac(compute, config, [1, 1, 2, 4], mesh, 1.0)
    assert 0.0 < frac < 1.0


def test_mesh_oracle_beats_boundary_only(db):
    """Adding the slice axis can only help: the (boundary, slice)
    optimum's throughput >= the boundary-only optimum under a balanced
    assignment, and is strictly better when compute is skewed."""
    scen = [0] * NUM_EPS
    mesh = resolve_mesh(HEAVY_MESH)
    cfg_b, tp_b = optimal_partition(db, scen, NUM_EPS)
    cfg_m, assign, tp_m = optimal_partition_mesh(db, scen, NUM_EPS, mesh)
    assert sum(assign) == mesh.devices and len(assign) == NUM_EPS
    assert sum(cfg_m) == db.num_layers

    # Evaluate the boundary-only config under the mesh cost model with
    # the balanced assignment — the best a boundary-only controller
    # could do on this hardware.
    prefix = db.prefix_times()

    def stage_compute(config):
        out, lo = [], 0
        for k, c in zip(scen, config):
            out.append(prefix[k][lo + c] - prefix[k][lo])
            lo += c
        return np.asarray(out)

    bal = balanced_assignment(mesh.devices, NUM_EPS)
    t_boundary = mesh_stage_times(stage_compute(cfg_b), cfg_b, bal,
                                  mesh, 1.0)
    t_mesh = mesh_stage_times(stage_compute(cfg_m), cfg_m, assign,
                              mesh, 1.0)
    assert max(t_mesh) <= max(t_boundary) + 1e-12
    assert tp_m >= tp_b - 1e-12


def test_mesh_scheduler_beats_static_under_mesh_event(db):
    """Under a mesh-contention episode, the mesh-aware odin explorer
    (slice moves in its action space) beats the static balanced
    config."""
    evs = mesh_events(600, factor=6.0)
    kw = dict(db=db, num_eps=NUM_EPS, num_queries=600, events=evs,
              mesh=HEAVY_MESH)
    odin = run(RunSpec(**kw, scheduler=SchedulerSpec(name="odin")))
    static = run(RunSpec(**kw, scheduler=SchedulerSpec(name="none")))
    assert odin.num_mesh_resizes >= 1
    assert float(np.percentile(odin.latencies, 99)) <= \
        float(np.percentile(static.latencies, 99))


# ---------------------------------------------------------------------------
# RunSpec: equivalence with the kwarg path, round-trip, dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched", ["odin", "lls", "none"])
def test_runspec_bit_identical_to_kwarg_simulate(db, sched):
    events = list(generate_events(400, NUM_EPS, db.num_scenarios, 20,
                                  10, seed=3))
    a = simulate(db, NUM_EPS, scheduler=sched, num_queries=400,
                 events=list(events))
    b = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=400,
                    events=events,
                    scheduler=SchedulerSpec(name=sched)))
    _same_trace(a, b)


def test_runspec_bit_identical_to_kwarg_cluster(db):
    events = [dataclasses.replace(ev, replica=1)
              for ev in generate_events(150, NUM_EPS, db.num_scenarios,
                                        2, 100, seed=5)]
    wl = dict(rate=0.01, seed=2)
    a = simulate_cluster(db, NUM_EPS, 3, scheduler="odin",
                         num_queries=300, events=list(events),
                         router="odin_aware", workload="poisson",
                         workload_kwargs=dict(wl))
    b = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=300,
                    events=events,
                    scheduler=SchedulerSpec(name="odin"),
                    workload=WorkloadSpec(name="poisson", kwargs=wl),
                    cluster=ClusterSpec(num_replicas=3,
                                        router="odin_aware")))
    assert np.array_equal(a.fleet.latencies, b.fleet.latencies)
    assert np.array_equal(a.assignments, b.assignments)
    sa, sb = a.summary(), b.summary()
    assert sa.keys() == sb.keys()


def test_cluster_n1_spec_still_returns_cluster_trace(db):
    """An n=1 ClusterSpec is a fleet, not a single pipeline."""
    ct = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=100,
                     cluster=ClusterSpec(num_replicas=1)))
    assert hasattr(ct, "fleet") and hasattr(ct, "assignments")


def test_runspec_json_round_trip(db):
    spec = RunSpec(db=db, num_eps=NUM_EPS, num_queries=300,
                   events=mesh_events(300), mesh=HEAVY_MESH,
                   scheduler=SchedulerSpec(name="odin", alpha=4),
                   workload=WorkloadSpec(name="poisson",
                                         kwargs={"rate": 0.02,
                                                 "seed": 1}),
                   admission=AdmissionSpec(name="queue_cap",
                                           kwargs={"cap": 16}))
    d = json.loads(json.dumps(spec.to_dict()))   # must be JSON-clean
    spec2 = RunSpec.from_dict(d, db=db)
    assert spec2 == spec
    a, b = run(spec), run(spec2)
    _same_trace(a, b)
    assert a.mesh_trace == b.mesh_trace


def test_runspec_dispatch_errors(db):
    with pytest.raises(ValueError, match="no target"):
        run(RunSpec(num_queries=10))
    with pytest.raises(TypeError):
        run({"db": db})
    with pytest.raises(NotImplementedError, match="cluster mesh"):
        run(RunSpec(db=db, num_queries=10, mesh=HEAVY_MESH,
                    cluster=ClusterSpec(num_replicas=2)))
    with pytest.raises(ValueError, match="fleet target"):
        run(RunSpec(db=db, num_queries=10,
                    faults=dict(hedge_after=1.0)))
    with pytest.raises(TypeError, match="SchedulerSpec"):
        RunSpec(db=db, scheduler="odin")


def test_runspec_subspecs_accept_dicts(db):
    a = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=200,
                    scheduler={"name": "lls"}))
    b = simulate(db, NUM_EPS, scheduler="lls", num_queries=200)
    _same_trace(a, b)


# ---------------------------------------------------------------------------
# sim/live parity (mesh armed on a real engine)
# ---------------------------------------------------------------------------


def test_sim_live_mesh_summary_key_parity(db):
    """A mesh-armed live engine reports the same mesh summary keys and
    trace surface as a mesh-armed simulation, and its unsharded twin
    reports none of them."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.serving import ServingEngine

    sim = run(RunSpec(db=db, num_eps=NUM_EPS, num_queries=300,
                      events=mesh_events(300), mesh=HEAVY_MESH))
    mesh_keys = {k for k in sim.summary()
                 if "mesh" in k or "collective" in k}
    assert mesh_keys == {"mesh_devices", "num_mesh_resizes",
                         "mean_collective_frac", "p99_collective_frac"}

    cfg = dc.replace(get_smoke_config("qwen2-0.5b"), num_layers=8)
    params = Model(cfg).init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)))
               for _ in range(20)]

    def cf_schedule(q):
        return 5.0 if 5 <= q < 12 else 1.0

    eng = ServingEngine(cfg, params, num_eps=NUM_EPS, scheduler="odin",
                        alpha=3, mesh=MeshSpec(devices=8,
                                               coll_cost=0.002),
                        coll_factor_schedule=cf_schedule)
    eng.executor.warmup(1, 64)
    live = eng.serve(queries, lambda q: [1.0] * NUM_EPS)
    assert live.mesh_devices == 8
    assert live.mesh_trace is not None
    assert all(sum(a) == 8 for a in live.mesh_trace)
    assert live.collective_fracs is not None
    assert mesh_keys <= live.summary().keys()

    plain = ServingEngine(cfg, params, num_eps=NUM_EPS,
                          scheduler="odin", alpha=3,
                          executor=eng.executor)
    unsharded = plain.serve(queries, lambda q: [1.0] * NUM_EPS)
    assert unsharded.mesh_devices == 0
    assert unsharded.mesh_trace is None
    assert not (mesh_keys & unsharded.summary().keys())
