"""Pallas kernel validation: interpret-mode sweeps vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.kernels import ops
from repro.kernels import ref as R

KEY = jax.random.PRNGKey(0)


def _rand(shape, k, dtype):
    x = jax.random.normal(k, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Hq, Hkv, S, D, causal, window, dtype
    (2, 4, 2, 256, 64, True, None, jnp.float32),
    (1, 8, 8, 128, 128, True, None, jnp.float32),   # MHA
    (2, 4, 1, 256, 64, False, None, jnp.float32),   # encoder + MQA
    (1, 4, 2, 512, 64, True, 128, jnp.float32),     # sliding window
    (1, 4, 2, 256, 80, True, None, jnp.float32),    # hubert head dim
    (1, 2, 2, 128, 56, True, None, jnp.float32),    # qwen2 head dim
    (2, 4, 2, 256, 64, True, None, jnp.bfloat16),
    (1, 4, 2, 512, 128, True, 256, jnp.bfloat16),
]


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D,causal,window,dtype", FLASH_CASES,
    ids=[f"B{c[0]}Hq{c[1]}Hkv{c[2]}S{c[3]}D{c[4]}c{int(c[5])}"
         f"w{c[6]}{jnp.dtype(c[7]).name}" for c in FLASH_CASES])
def test_flash_attention(B, Hq, Hkv, S, D, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand((B, Hq, S, D), ks[0], dtype)
    k = _rand((B, Hkv, S, D), ks[1], dtype)
    v = _rand((B, Hkv, S, D), ks[2], dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="interpret", block_q=64, block_k=64)
    refo = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refo, np.float32), **_tol(dtype))


@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64, 128]))
@settings(max_examples=6, deadline=None)
def test_flash_block_shape_independence(bq, bk):
    """Output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(KEY, 3)
    q = _rand((1, 2, 256, 64), ks[0], jnp.float32)
    k = _rand((1, 2, 256, 64), ks[1], jnp.float32)
    v = _rand((1, 2, 256, 64), ks[2], jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, impl="interpret",
                              block_q=bq, block_k=bk)
    refo = R.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refo), atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 8, 2, 512, 64, 300, None, jnp.float32),
    (1, 4, 4, 256, 128, 17, None, jnp.float32),
    (2, 8, 2, 512, 64, 400, 128, jnp.float32),      # sliding window
    (1, 14, 2, 256, 64, 255, None, jnp.float32),    # qwen2 ratios
    (2, 8, 2, 512, 64, 300, None, jnp.bfloat16),
]


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D,idx,window,dtype", DECODE_CASES,
    ids=[f"B{c[0]}Hq{c[1]}S{c[3]}i{c[5]}w{c[6]}{jnp.dtype(c[7]).name}"
         for c in DECODE_CASES])
def test_decode_attention(B, Hq, Hkv, S, D, idx, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = _rand((B, Hq, D), ks[0], dtype)
    k = _rand((B, Hkv, S, D), ks[1], dtype)
    v = _rand((B, Hkv, S, D), ks[2], dtype)
    out = ops.decode_attention(q, k, v, jnp.int32(idx), window=window,
                               impl="interpret", block_k=128)
    refo = R.decode_attention_ref(q, k, v, idx, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refo, np.float32), **_tol(dtype))


def test_decode_ignores_stale_cache_beyond_index():
    """Slots past `index` must not leak into the output."""
    ks = jax.random.split(KEY, 3)
    q = _rand((1, 4, 2, 64)[0:3] + (64,), ks[0], jnp.float32)
    q = _rand((1, 4, 64), ks[0], jnp.float32)
    k = _rand((1, 2, 256, 64), ks[1], jnp.float32)
    v = _rand((1, 2, 256, 64), ks[2], jnp.float32)
    out1 = ops.decode_attention(q, k, v, jnp.int32(100), impl="interpret",
                                block_k=64)
    k2 = k.at[:, :, 101:].set(99.0)
    v2 = v.at[:, :, 101:].set(-99.0)
    out2 = ops.decode_attention(q, k2, v2, jnp.int32(100), impl="interpret",
                                block_k=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    (2, 256, 8, 64, 32, 64, 4, jnp.float32),
    (1, 128, 4, 32, 64, 32, 4, jnp.float32),
    (1, 256, 16, 64, 128, 64, 8, jnp.float32),      # mamba2-370m dims
    (2, 128, 8, 64, 32, 32, 8, jnp.bfloat16),
]


@pytest.mark.parametrize(
    "b,S,H,P,N,chunk,bh,dtype", SSD_CASES,
    ids=[f"b{c[0]}S{c[1]}H{c[2]}P{c[3]}N{c[4]}{jnp.dtype(c[7]).name}"
         for c in SSD_CASES])
def test_ssd_scan(b, S, H, P, N, chunk, bh, dtype):
    ks = jax.random.split(KEY, 5)
    x = _rand((b, S, H, P), ks[0], dtype)
    dt = jax.nn.softplus(_rand((b, S, H), ks[1], jnp.float32)).astype(dtype)
    A = -jnp.exp(_rand((H,), ks[2], jnp.float32) * 0.5)
    B_ = _rand((b, S, N), ks[3], dtype)
    C = _rand((b, S, N), ks[4], dtype)
    out = ops.ssd_scan(x, dt, A.astype(dtype), B_, C, chunk=chunk,
                       block_h=bh, impl="interpret")
    refo = R.ssd_scan_ref(x, dt, A, B_, C)
    scale = float(np.max(np.abs(np.asarray(refo, np.float32)))) + 1e-9
    err = np.max(np.abs(np.asarray(out, np.float32)
                        - np.asarray(refo, np.float32))) / scale
    assert err < (5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_matches_model_chunked_form():
    """Kernel == models.mamba2.ssd_chunked == naive recurrence."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(KEY, 5)
    b, S, H, P, N = 2, 256, 8, 64, 32
    x = _rand((b, S, H, P), ks[0], jnp.float32)
    dt = jax.nn.softplus(_rand((b, S, H), ks[1], jnp.float32))
    A = -jnp.exp(_rand((H,), ks[2], jnp.float32) * 0.5)
    B_ = _rand((b, S, N), ks[3], jnp.float32)
    C = _rand((b, S, N), ks[4], jnp.float32)
    y_kernel = ops.ssd_scan(x, dt, A, B_, C, chunk=64, block_h=4,
                            impl="interpret")
    y_model, _ = ssd_chunked(x, dt, A, B_, C, chunk=64)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=5e-4, rtol=1e-4)
