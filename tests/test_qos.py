"""QoS tiers (repro.qos, docs/QOS.md): registry, stamping, tier-aware
control plane, heterogeneous fleets.

The determinism groups pin the two identities the subsystem is built
on: tiers default *off* (a ``tiers=None`` run is bit-identical to a
pre-QoS run), and stamping is *passive* (arming tiers changes only the
accounting, never the service timeline).  The scenario group then
checks the value: EDF + value-aware shedding on a heterogeneous fleet
beats both tier-blind shedding and a fleet-blind router on realized
value under bursty overload.
"""
import math

import numpy as np
import pytest

from repro.cluster import simulate_cluster
from repro.core.database import synthetic_database
from repro.core.simulator import simulate
from repro.qos import (QosTier, TierAssigner, TierPlan, available_tiers,
                       get_tier, register_tier, resolve_tiers,
                       unregister_tier)


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


TIERS = "interactive,best_effort"
TK = dict(shares=[0.25, 0.75], seed=3)


def _same_trace(a, b):
    assert np.array_equal(a.latencies, b.latencies)
    assert np.array_equal(a.throughputs, b.throughputs)
    sa, sb = a.summary(), b.summary()
    assert set(sa) == set(sb)
    for k, v in sa.items():
        if isinstance(v, float) and math.isnan(v):
            assert math.isnan(sb[k])
        else:
            assert sb[k] == v, k


# ------------------------------------------------------------------
# Registry round-trip + validation
# ------------------------------------------------------------------

def test_tier_registry_round_trip():
    t = QosTier("pr9_test_tier", priority=5, value=3.0, deadline=1.5)
    register_tier(t)
    try:
        assert "pr9_test_tier" in available_tiers()
        assert get_tier("pr9_test_tier") is t
        with pytest.raises(ValueError, match="already registered"):
            register_tier(QosTier("pr9_test_tier"))
        plan = resolve_tiers("pr9_test_tier,best_effort", num_queries=10)
        assert plan.names == ("pr9_test_tier", "best_effort")
    finally:
        unregister_tier("pr9_test_tier")
    assert "pr9_test_tier" not in available_tiers()
    with pytest.raises(ValueError, match="unknown tier"):
        get_tier("pr9_test_tier")


def test_tier_validation():
    with pytest.raises(ValueError):
        QosTier("")                        # empty name
    with pytest.raises(ValueError):
        QosTier("x", value=0.0)            # non-positive value
    with pytest.raises(ValueError):
        QosTier("x", deadline=-1.0).deadline_sampler()
    with pytest.raises(ValueError):
        TierAssigner([])                   # no tiers
    with pytest.raises(ValueError, match="unique"):
        TierAssigner([QosTier("a"), QosTier("a")])
    with pytest.raises(ValueError, match="shares"):
        TierAssigner([QosTier("a")], shares=[0.0])
    with pytest.raises(ValueError, match="tiers_kwargs"):
        resolve_tiers(None, tiers_kwargs=dict(seed=1))


def test_assigner_deterministic_and_resolve_forms():
    tiers = [get_tier("interactive"), get_tier("best_effort")]
    a = TierAssigner(tiers, shares=[0.3, 0.7], seed=9)
    p1, p2 = a.assign(200), a.assign(200)
    assert np.array_equal(p1.tier_ids, p2.tier_ids)
    assert np.array_equal(p1.deadlines, p2.deadlines)
    # mixture shares are roughly honoured
    assert 0.15 < np.mean(p1.tier_ids == 0) < 0.45
    # each spec form yields the identical plan
    forms = [
        "interactive,best_effort",
        tiers,
        [dict(name="interactive", priority=2, value=10.0, deadline=0.5),
         dict(name="best_effort", priority=0, value=1.0, deadline=10.0)],
    ]
    for spec in forms:
        p = resolve_tiers(spec, dict(shares=[0.3, 0.7], seed=9),
                          num_queries=200)
        assert np.array_equal(p.tier_ids, p1.tier_ids)
        assert np.array_equal(p.deadlines, p1.deadlines)
        assert np.array_equal(p.values, p1.values)
    # a pre-built plan passes through (truncated), stamps copy exactly
    assert resolve_tiers(p1, num_queries=50).tier_ids.shape == (50,)
    empty = TierPlan.empty(tiers, 4)
    empty.stamp(2, p1, 7)
    assert empty.tier_ids[2] == p1.tier_ids[7]
    assert empty.deadlines[2] == p1.deadlines[7]


# ------------------------------------------------------------------
# Chunked == scalar with tiers armed
# ------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["odin", "lls", "none"])
def test_chunked_scalar_identity_with_tiers(db, scheduler):
    """The vectorized tick must stay bit-identical to the scalar tick
    with tier stamping armed (the tier reads index the same global
    query ids either way)."""
    kw = dict(scheduler=scheduler, num_queries=300, freq_period=2,
              duration=100, tiers=TIERS, tiers_kwargs=TK)
    chunked = simulate(db, 4, chunking=True, **kw)
    scalar = simulate(db, 4, chunking=False, **kw)
    _same_trace(chunked, scalar)
    s = chunked.summary()
    for t in ("interactive", "best_effort"):
        assert f"tier_{t}_p99_latency_s" in s
        assert 0.0 <= s[f"tier_{t}_deadline_attainment"] <= 1.0


# ------------------------------------------------------------------
# Tiers default off / stamping is passive
# ------------------------------------------------------------------

def test_no_tiers_bit_identical(db):
    """``tiers=None`` must leave the trace bit-identical to a call
    that never mentions tiers, with zero tier keys in the summary."""
    base = simulate(db, 4, num_queries=300)
    off = simulate(db, 4, num_queries=300, tiers=None)
    _same_trace(base, off)
    assert not any(k.startswith("tier_") for k in base.summary())
    assert "realized_value" not in base.summary()


def test_tier_stamping_is_passive(db):
    """Arming tiers adds accounting only: latencies, throughputs and
    the rebalance trail are bit-identical to the untier-ed run."""
    base = simulate(db, 4, num_queries=300)
    tiered = simulate(db, 4, num_queries=300, tiers=TIERS, tiers_kwargs=TK)
    assert np.array_equal(base.latencies, tiered.latencies)
    assert np.array_equal(base.throughputs, tiered.throughputs)
    assert base.num_rebalances == tiered.num_rebalances
    s = tiered.summary()
    # preset deadlines are wall-clock seconds; sim time units dwarf
    # them, so realized value may legitimately be zero here
    assert s["offered_value"] > 0
    assert 0 <= s["realized_value"] <= s["offered_value"]


def test_no_tiers_cluster_bit_identical(db):
    kw = dict(scheduler="none", num_queries=240, workload="poisson",
              workload_kwargs=dict(rate=0.02, seed=5))
    base = simulate_cluster(db, 4, 3, **kw)
    off = simulate_cluster(db, 4, 3, tiers=None, **kw)
    assert np.array_equal(base.assignments, off.assignments)
    _same_trace(base.fleet, off.fleet)


# ------------------------------------------------------------------
# The acceptance scenario: value-aware control beats blind baselines
# ------------------------------------------------------------------

FULL = synthetic_database("vgg16", base_time=10.0, seed=0)
SMALL = synthetic_database("vgg16", base_time=5.0, seed=0)
GOLD_BATCH = [dict(name="gold", priority=2, value=10.0, deadline=800.0),
              dict(name="batch", priority=0, value=1.0, deadline=6000.0)]


def _overload_run(router, admission, rk=None, ak=None, n=400, **extra):
    return simulate_cluster(
        FULL, 4, num_replicas=4,
        databases=[FULL, FULL, SMALL, SMALL],
        pools=["default", "default", "small", "small"],
        scheduler="none",
        router=router, router_kwargs=rk,
        admission=admission, admission_kwargs=ak,
        num_queries=n,
        tiers=GOLD_BATCH, tiers_kwargs=dict(shares=[0.15, 0.85], seed=5),
        workload="bursty",
        workload_kwargs=dict(burst_rate=0.16, base_rate=0.004,
                             mean_burst=400.0, mean_gap=400.0, seed=7),
        **extra)


def test_value_aware_beats_blind_baselines_under_overload():
    """Bursty overload on a heterogeneous 4-replica fleet: downgrade
    routing + expected-value shedding must realize more SLO value than
    the same router with tier-blind slo_shed AND than a fleet-blind
    round robin, while holding gold-tier attainment >= 0.99."""
    qos = _overload_run("downgrade", "value_shed",
                        rk=dict(pressure=0.0, priority_max=0),
                        ak=dict(theta=0.5)).summary()
    blind = _overload_run("downgrade", "slo_shed",
                          rk=dict(pressure=0.0, priority_max=0),
                          ak=dict(slo=800.0)).summary()
    rr = _overload_run("round_robin", None).summary()
    assert qos["tier_gold_deadline_attainment"] >= 0.99
    assert qos["realized_value"] > blind["realized_value"]
    assert qos["realized_value"] > rr["realized_value"]
    # the fleet-blind baseline actually violates the gold objective
    assert rr["tier_gold_deadline_attainment"] < 0.99
    # downgrades flowed to the small pool instead of shedding gold
    assert qos["tier_batch_downgraded"] > 0
    assert qos.get("tier_gold_downgraded", 0) == 0


def _weighted_attainment(s):
    return (10.0 * s["tier_gold_deadline_attainment"]
            + s["tier_batch_deadline_attainment"])


def test_deadline_aware_beats_fifo_on_weighted_attainment():
    """Deadline/value awareness pays on weighted attainment under
    overload, at both layers: the EDF cost atop odin_aware beats plain
    (deadline-blind) odin_aware, and the full tier-aware stack —
    downgrade routing + expected-value shedding — beats FIFO
    round robin + tier-blind slo_shed."""
    edf = _overload_run("edf", None, n=300).summary()
    oa = _overload_run("odin_aware", None, n=300).summary()
    for t in ("gold", "batch"):
        assert f"tier_{t}_deadline_attainment" in edf
    assert _weighted_attainment(edf) > _weighted_attainment(oa)
    stack = _overload_run("downgrade", "value_shed", n=300,
                          rk=dict(pressure=0.0, priority_max=0),
                          ak=dict(theta=0.5)).summary()
    fifo = _overload_run("round_robin", "slo_shed", n=300,
                         ak=dict(slo=800.0)).summary()
    assert _weighted_attainment(stack) > _weighted_attainment(fifo)


def test_dense_streaming_tier_parity():
    """Per-tier percentiles from the streaming sketches must stay
    within 1% of the dense trace (acceptance bound; observed exact on
    this scenario)."""
    kw = dict(rk=dict(pressure=0.0, priority_max=0), ak=dict(theta=0.5))
    dense = _overload_run("downgrade", "value_shed", **kw).summary()
    stream = _overload_run("downgrade", "value_shed",
                           trace_mode="streaming", **kw).summary()
    for t in ("gold", "batch"):
        for q in ("p50", "p99"):
            k = f"tier_{t}_{q}_latency_s"
            assert stream[k] == pytest.approx(dense[k], rel=0.01)
        assert stream[f"tier_{t}_deadline_attainment"] == pytest.approx(
            dense[f"tier_{t}_deadline_attainment"], abs=1e-12)
    assert stream["realized_value"] == pytest.approx(
        dense["realized_value"], rel=1e-9)


# ------------------------------------------------------------------
# Heterogeneous fleet identities
# ------------------------------------------------------------------

def test_hetero_single_replica_matches_single_pipeline(db):
    """An n=1 'fleet' whose one replica runs the small model must be
    bit-identical to a single-pipeline simulate() on that model —
    per-database configs/peaks/oracles change nothing at n=1."""
    small = synthetic_database("vgg16", base_time=5.0, seed=0)
    ct = simulate_cluster(db, 4, num_replicas=1, databases=[small],
                          scheduler="odin", num_queries=200,
                          tiers=TIERS, tiers_kwargs=TK)
    single = simulate(small, 4, scheduler="odin", num_queries=200,
                      events=[], chunking=False,
                      tiers=TIERS, tiers_kwargs=TK)
    assert np.array_equal(ct.fleet.latencies, single.latencies)
    sa, sb = ct.fleet.summary(), single.summary()
    for t in ("interactive", "best_effort"):
        for k in ("num", "p99_latency_s", "deadline_attainment"):
            assert sa[f"tier_{t}_{k}"] == sb[f"tier_{t}_{k}"]


def test_hetero_peaks_and_weighted_fleet_peak(db):
    """Distinct databases get distinct clean peaks, and the fleet peak
    is the served-share-weighted mean of the per-replica peaks."""
    small = synthetic_database("vgg16", base_time=5.0, seed=0)
    ct = simulate_cluster(db, 4, num_replicas=2, databases=[db, small],
                          scheduler="none", num_queries=120)
    p0, p1 = (t.peak_throughput for t in ct.replicas)
    assert p1 > p0    # half the base_time, higher clean peak
    cnt = ct.replica_counts.astype(float)
    expect = (cnt[0] * p0 + cnt[1] * p1) / cnt.sum()
    assert ct.fleet.peak_throughput == pytest.approx(expect)
    # homogeneous fleets collapse to the replica peak
    hom = simulate_cluster(db, 4, num_replicas=2, scheduler="none",
                           num_queries=120)
    assert hom.fleet.peak_throughput == pytest.approx(
        hom.replicas[0].peak_throughput)


# ------------------------------------------------------------------
# Live downgrade smoke (real JAX engines)
# ------------------------------------------------------------------

def test_live_downgrade_smoke():
    """Two live engines, one labelled ``small``: a tiered open-loop run
    under the downgrade router must stamp tiers sim/live-identically,
    surface the per-tier summary keys, and send pressured best-effort
    traffic to the small pool."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.cluster import serve_cluster
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=8)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)))
               for _ in range(48)]
    engines = [ServingEngine(cfg, params, num_eps=4, scheduler="none")]
    engines[0].executor.warmup(1, 64)
    engines.append(ServingEngine(cfg, params, num_eps=4, scheduler="none",
                                 executor=engines[0].executor))
    probe = engines[0].serve(queries[:6], lambda q: [1.0] * 4)
    service = float(probe.service_latencies[2:].mean())
    engines[0].reset_policy()
    # 2x the full replica's service rate: the full pool stays backed
    # up, so pressured best-effort arrivals must flow to the small pool.
    ct = serve_cluster(engines, queries, lambda q: [1.0] * 4,
                       workload="poisson",
                       workload_kwargs=dict(rate=2.0 / service, seed=3),
                       router="downgrade",
                       router_kwargs=dict(pressure=0.0, priority_max=0),
                       pools=["default", "small"],
                       tiers=TIERS, tiers_kwargs=TK)
    assert ct.num_queries == len(queries)
    s = ct.summary()
    for t in ("interactive", "best_effort"):
        assert f"tier_{t}_num" in s
        assert f"tier_{t}_downgraded" in s
    assert s["tier_best_effort_downgraded"] > 0
    assert s["tier_interactive_downgraded"] == 0
    assert s["realized_value"] <= s["offered_value"]
    # the tier sequence is the seeded draw — identical to the sim side
    plan = resolve_tiers(TIERS, TK, num_queries=len(queries))
    assert np.array_equal(ct.fleet.tier_ids, plan.tier_ids)
