"""repro.cluster: router registry, determinism, the n=1 reduction, and
interference-aware routing beating the baselines in both backends."""
import dataclasses

import numpy as np
import pytest

from repro.cluster import (
    Cluster,
    ClusterTrace,
    ReplicaView,
    Router,
    available_routers,
    make_router,
    register_router,
    router_class,
    simulate_cluster,
    unregister_router,
)
from repro.core import (
    InterferenceEvent,
    generate_events,
    simulate,
    synthetic_database,
)

BUILTIN_ROUTERS = ("round_robin", "least_outstanding", "odin_aware")


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


@pytest.fixture(scope="module")
def cap(db):
    """Per-replica interference-free peak throughput."""
    return simulate(db, 4, scheduler="none", events=[],
                    num_queries=10).peak_throughput


def replica2_events(num_local_queries=500, freq=2, dur=100, seed=5,
                    num_scenarios=12):
    """The acceptance scenario: the paper's heaviest setting
    (freq=2, dur=100) scoped to replica 2 of 4."""
    return [dataclasses.replace(ev, replica=2)
            for ev in generate_events(num_local_queries, 4, num_scenarios,
                                      freq, dur, seed)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_routers():
    names = available_routers()
    for name in BUILTIN_ROUTERS:
        assert name in names


def test_registry_kwargs_filtered_per_router():
    """One kwargs superset constructs any router (round_robin ignores
    the odin_aware knobs)."""
    for name in BUILTIN_ROUTERS:
        r = make_router(name, interference_weight=2.0, explore_penalty=3.0)
        assert isinstance(r, Router)
    assert make_router("odin_aware",
                       interference_weight=2.0).interference_weight == 2.0


def test_registry_unknown_and_custom():
    with pytest.raises(ValueError, match="unknown router"):
        make_router("does-not-exist")

    @register_router("_test_sticky")
    class StickyRouter:
        def route(self, q, now, views):
            return 0

        def reset(self):
            pass

    try:
        assert router_class("_test_sticky") is StickyRouter
        assert make_router("_test_sticky").name == "_test_sticky"
    finally:
        unregister_router("_test_sticky")
    with pytest.raises(ValueError):
        make_router("_test_sticky")


def test_cluster_validates_replicas_and_router_output(db):
    with pytest.raises(ValueError, match="at least one replica"):
        Cluster([], router="round_robin")

    class BadRouter:
        name = "bad"

        def route(self, q, now, views):
            return 7

        def reset(self):
            pass

    with pytest.raises(ValueError, match="position 7"):
        simulate_cluster(db, 4, 2, scheduler="none", router=BadRouter(),
                         num_queries=4)


# ---------------------------------------------------------------------------
# determinism: same (workload, seed, router) => identical assignments
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("router", BUILTIN_ROUTERS)
def test_router_assignments_deterministic(db, cap, router):
    evs = replica2_events(num_local_queries=200)
    kw = dict(scheduler="odin", alpha=4, num_queries=400, events=evs,
              router=router, workload="poisson",
              workload_kwargs=dict(rate=2.5 * cap, seed=7))
    a = simulate_cluster(db, 4, 4, **kw)
    b = simulate_cluster(db, 4, 4, **kw)
    assert np.array_equal(a.assignments, b.assignments)
    assert np.array_equal(a.local_indices, b.local_indices)
    assert np.array_equal(a.fleet.latencies, b.fleet.latencies)
    # every replica's per-query trace replays identically too
    for ta, tb in zip(a.replicas, b.replicas):
        assert np.array_equal(ta.latencies, tb.latencies)
        assert ta.configs_trace == tb.configs_trace


def test_routers_actually_differ(db, cap):
    """Sanity: the three routers are not secretly the same policy."""
    evs = replica2_events(num_local_queries=200)
    kw = dict(scheduler="odin", alpha=4, num_queries=400, events=evs,
              workload="poisson",
              workload_kwargs=dict(rate=2.5 * cap, seed=7))
    rr = simulate_cluster(db, 4, 4, router="round_robin", **kw)
    lo = simulate_cluster(db, 4, 4, router="least_outstanding", **kw)
    oa = simulate_cluster(db, 4, 4, router="odin_aware", **kw)
    assert not np.array_equal(rr.assignments, lo.assignments)
    assert not np.array_equal(rr.assignments, oa.assignments)
    # round robin splits exactly evenly
    assert np.array_equal(rr.replica_counts, [100, 100, 100, 100])


# ---------------------------------------------------------------------------
# the n=1 reduction: a one-replica cluster IS run_pipeline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("router", BUILTIN_ROUTERS)
def test_cluster_n1_closed_loop_bit_identical_to_simulate(db, router):
    """cluster(n=1, router=*) closed loop == plain simulate(), bit for
    bit: same arrays, same rebalance accounting, same references."""
    events = generate_events(400, 4, db.num_scenarios, 20, 10, seed=3)
    ref = simulate(db, 4, scheduler="odin", alpha=4, num_queries=400,
                   events=list(events))
    ct = simulate_cluster(db, 4, 1, scheduler="odin", alpha=4,
                          num_queries=400, events=list(events),
                          router=router)
    assert np.array_equal(ct.assignments, np.zeros(400, dtype=int))
    f = ct.fleet
    assert np.array_equal(f.latencies, ref.latencies)
    assert np.array_equal(f.throughputs, ref.throughputs)
    assert np.array_equal(f.serial_mask, ref.serial_mask)
    assert f.configs_trace == ref.configs_trace
    assert np.array_equal(f.service_latencies, ref.service_latencies)
    assert np.array_equal(f.queue_delays, ref.queue_delays)
    assert np.array_equal(f.queue_depths, ref.queue_depths)
    assert np.array_equal(f.arrival_times, ref.arrival_times)
    assert np.array_equal(f.completion_times, ref.completion_times)
    assert np.array_equal(f.rc_throughputs, ref.rc_throughputs)
    assert f.num_rebalances == ref.num_rebalances
    assert f.total_trials == ref.total_trials
    assert f.mitigation_lengths == ref.mitigation_lengths
    assert f.peak_throughput == ref.peak_throughput
    assert f.summary() == ref.summary()


def test_cluster_n1_open_loop_matches_simulate(db, cap):
    """Open loop: the cluster's scalar tick vs simulate()'s chunked
    fast path — equal to float re-association (<= 1e-9 rel)."""
    events = generate_events(300, 4, db.num_scenarios, 20, 10, seed=3)
    kw = dict(num_queries=300, workload="poisson",
              workload_kwargs=dict(rate=0.8 * cap, seed=11))
    ref = simulate(db, 4, scheduler="odin", alpha=4, events=list(events),
                   **kw)
    ct = simulate_cluster(db, 4, 1, scheduler="odin", alpha=4,
                          events=list(events), **kw)
    f = ct.fleet
    assert np.allclose(f.latencies, ref.latencies, rtol=1e-9)
    assert np.allclose(f.queue_delays, ref.queue_delays, rtol=1e-9,
                       atol=1e-9)
    assert f.configs_trace == ref.configs_trace
    assert f.num_rebalances == ref.num_rebalances


# ---------------------------------------------------------------------------
# replica-scoped interference + routing: the acceptance scenario (sim)
# ---------------------------------------------------------------------------


def test_odin_aware_beats_baselines_under_replica_scoped_interference(
        db, cap):
    """freq=2, dur=100 hammering replica 2 of 4: interference-aware
    routing must sustain fleet p99 latency and throughput strictly
    better than round_robin and no worse than least_outstanding.
    The simulator is deterministic, so the comparisons are strict."""
    evs = replica2_events()
    res = {}
    for router in BUILTIN_ROUTERS:
        res[router] = simulate_cluster(
            db, 4, 4, scheduler="odin", alpha=4, num_queries=2000,
            events=evs, router=router, workload="poisson",
            workload_kwargs=dict(rate=2.5 * cap, seed=7))
    rr, lo, oa = (res["round_robin"], res["least_outstanding"],
                  res["odin_aware"])
    # p99 latency: strictly better than RR, no worse than cluster-LLS
    assert oa.tail_latency(99) < rr.tail_latency(99)
    assert oa.tail_latency(99) <= lo.tail_latency(99)
    # throughput: strictly better than RR, no worse than cluster-LLS
    assert oa.achieved_load > rr.achieved_load
    assert oa.achieved_load >= lo.achieved_load
    # SLO violations follow the same ordering
    assert oa.slo_violations(0.9) < rr.slo_violations(0.9)
    assert oa.slo_violations(0.9) <= lo.slo_violations(0.9)
    # and the mechanism is visible: odin_aware starves the interfered
    # replica while RR keeps feeding it its full 1/4 share
    assert oa.replica_counts[2] < lo.replica_counts[2]
    assert lo.replica_counts[2] < rr.replica_counts[2]


def test_replica_scoped_event_hits_only_its_replica(db, cap):
    """With a fixed (round_robin) assignment, adding a replica-2-scoped
    event changes replica 2's trace and nothing else."""
    kw = dict(scheduler="none", num_queries=400, router="round_robin",
              workload="poisson",
              workload_kwargs=dict(rate=2.0 * cap, seed=3))
    base = simulate_cluster(db, 4, 4, events=[], **kw)
    evs = [InterferenceEvent(start=10, duration=60, ep=1, scenario=12,
                             replica=2)]
    hit = simulate_cluster(db, 4, 4, events=evs, **kw)
    assert np.array_equal(base.assignments, hit.assignments)
    for r in (0, 1, 3):
        assert np.array_equal(base.replicas[r].service_latencies,
                              hit.replicas[r].service_latencies)
    assert not np.array_equal(base.replicas[2].service_latencies,
                              hit.replicas[2].service_latencies)
    # local query-indexed window: exactly local queries [10, 70) differ
    diff = np.flatnonzero(base.replicas[2].service_latencies
                          != hit.replicas[2].service_latencies)
    assert diff.min() >= 10 and diff.max() < 70


def test_time_indexed_cluster_events_reject_closed_loop(db):
    evs = [InterferenceEvent(start=0.0, duration=10.0, ep=0, scenario=1,
                             replica=0)]
    with pytest.raises(ValueError, match="open-loop"):
        simulate_cluster(db, 4, 2, scheduler="none", events=evs,
                         events_time_indexed=True, num_queries=4)


def test_time_indexed_replica_event_anchors_on_fleet_clock(db, cap):
    """A wall-clock event window on replica 2: the affected local
    queries are exactly those whose *fleet arrival times* fall inside
    the window, however many the router happened to send."""
    kw = dict(scheduler="none", num_queries=400, router="round_robin",
              workload="poisson",
              workload_kwargs=dict(rate=2.0 * cap, seed=3))
    base = simulate_cluster(db, 4, 4, events=[], **kw)
    t0, t1 = 20000.0, 60000.0
    evs = [InterferenceEvent(start=t0, duration=t1 - t0, ep=1,
                             scenario=12, replica=2)]
    hit = simulate_cluster(db, 4, 4, events=evs,
                           events_time_indexed=True, **kw)
    assert np.array_equal(base.assignments, hit.assignments)
    for r in (0, 1, 3):
        assert np.array_equal(base.replicas[r].service_latencies,
                              hit.replicas[r].service_latencies)
    arr = hit.replicas[2].arrival_times
    in_win = (arr >= t0) & (arr < t1)
    assert 0 < in_win.sum() < len(in_win)
    slower = (hit.replicas[2].service_latencies
              > base.replicas[2].service_latencies)
    assert np.array_equal(slower, in_win)


# ---------------------------------------------------------------------------
# ClusterTrace surface
# ---------------------------------------------------------------------------


def test_cluster_trace_surface(db, cap):
    ct = simulate_cluster(db, 4, 3, scheduler="odin", alpha=4,
                          num_queries=300,
                          events=replica2_events(num_local_queries=150),
                          router="odin_aware", workload="bursty",
                          workload_kwargs=dict(burst_rate=4.0 * cap,
                                               base_rate=0.2 * cap,
                                               mean_burst=3000,
                                               mean_gap=5000, seed=2))
    assert isinstance(ct, ClusterTrace)
    assert ct.num_replicas == 3 and ct.num_queries == 300
    assert ct.replica_counts.sum() == 300
    # the fleet trace is a permutation of the replica traces
    fleet = ct.fleet
    concat = np.sort(np.concatenate([t.latencies for t in ct.replicas]))
    assert np.array_equal(np.sort(fleet.latencies), concat)
    # fleet arrival order really is arrival order
    assert np.all(np.diff(fleet.arrival_times) >= 0)
    s = ct.summary()
    for key in ("p50_latency_s", "p99_latency_s", "mean_queue_delay_s",
                "offered_load_qps", "achieved_load_qps", "slo_violations",
                "rebalances", "num_replicas", "router",
                "min_replica_share", "max_replica_share"):
        assert key in s
    assert s["num_replicas"] == 3 and s["router"] == "odin_aware"
    assert 0.0 <= s["slo_violations"] <= 1.0
    assert 0.0 <= s["min_replica_share"] <= s["max_replica_share"] <= 1.0
    # per-replica + fleet rows share one schema
    rows = ct.rows()
    assert len(rows) == 4
    assert [r["scope"] for r in rows] == ["replica0", "replica1",
                                          "replica2", "fleet"]
    keys = set(rows[0])
    assert all(set(r) == keys for r in rows)
    # rebalance accounting aggregates
    assert fleet.num_rebalances == sum(t.num_rebalances
                                       for t in ct.replicas)


def test_replica_view_signals(db, cap):
    """The view's detector/estimate probes reflect replica state and
    are side-effect-free (probing twice changes nothing)."""
    from repro.workloads.runner import PipelineRunner
    from repro.cluster.sim import simulate_cluster  # noqa: F401

    # build one interfered replica by hand via the sim backend pieces
    evs = [InterferenceEvent(start=5, duration=100, ep=1, scenario=12)]
    ct = simulate_cluster(db, 4, 1, scheduler="odin", alpha=4,
                          num_queries=3, events=evs, router="round_robin")
    assert ct.num_queries == 3  # smoke: the machinery above ran

    # direct probe: a runner served past the event edge reports a
    # positive interference score on a quiet detector reference
    from repro.core.simulator import DatabaseQueryExecutor
    from repro.core.exhaustive import optimal_partition
    from repro.schedulers.registry import make_scheduler
    from repro.schedulers.runtime import RebalanceRuntime

    def oracle(scen_key):
        return optimal_partition(db, list(scen_key), 4)

    ex = DatabaseQueryExecutor(db, 4, evs, oracle)
    policy = make_scheduler("none")      # no mitigation: shift persists
    rt = RebalanceRuntime(policy, [4, 4, 4, 4])
    runner = PipelineRunner(ex, rt, 20)
    assert rt.interference_score() == 0.0        # nothing polled yet
    assert np.isnan(rt.estimated_bottleneck())
    for _ in range(4):
        runner.step(None)
    view = ReplicaView(0, runner, outstanding=2, now=0.0,
                       since_assign=1.0)
    assert view.interference_score == 0.0        # static policy: no det
    assert np.isfinite(view.est_bottleneck)
    assert view.backlog == runner.free_at        # now=0, free_at ahead

    # with a detector-bearing policy the shift becomes visible
    policy = make_scheduler("lls")
    rt = RebalanceRuntime(policy, [4, 4, 4, 4])
    ex = DatabaseQueryExecutor(db, 4, evs, oracle)
    runner = PipelineRunner(ex, rt, 20)
    runner.step(None)                    # q=0: arms the clean reference
    for _ in range(5):                   # cross the event edge at q=5
        runner.step(None)
    # the detector triggered and the runtime is mid-exploration (LLS
    # trials); the probe sees the phase without advancing it
    view = ReplicaView(0, runner, 0, now=0.0, since_assign=1.0)
    assert view.exploring
    before = (rt.num_rebalances, rt.total_trials)
    _ = (view.interference_score, view.est_bottleneck,
         view.interference_active)
    _ = (view.interference_score, view.est_bottleneck)
    assert (rt.num_rebalances, rt.total_trials) == before
