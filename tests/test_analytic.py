"""Analytic cost model validated against XLA cost_analysis (unrolled HLO)."""
import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.launch.analytic import analytic_totals
from repro.launch import steps as st


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b",
                                  "mamba2-370m", "hubert-xlarge"])
def test_analytic_flops_vs_hlo_train(arch):
    """Unrolled-HLO cost_analysis agrees with the analytic model ±25%."""
    cfg = get_smoke_config(arch)
    shape = InputShape("tiny_train", 128, 4, "train")
    fn = st.make_train_step_fn(cfg, unroll=True)
    params_sh = st.param_shapes(cfg)
    opt_sh = st.opt_state_shapes(params_sh)
    specs = st.input_specs(cfg, shape)
    c = jax.jit(fn).lower(params_sh, opt_sh,
                          specs["batch"]).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    hlo = float(c.get("flops", 0.0))
    ana = analytic_totals(cfg, shape, remat=True)["flops"]
    assert hlo == pytest.approx(ana, rel=0.25)


def test_analytic_scaling_laws():
    """Analytic model scales correctly in S, B, and L."""
    cfg = get_smoke_config("qwen3-8b")
    f = lambda s, b: analytic_totals(
        cfg, InputShape("x", s, b, "train"))["flops"]
    # doubling batch doubles flops
    assert f(128, 8) == pytest.approx(2 * f(128, 4), rel=1e-6)
    # doubling seq more than doubles (attention quadratic term)
    assert f(256, 4) > 2 * f(128, 4)
    cfg2 = dataclasses.replace(cfg, num_layers=4)
    assert analytic_totals(cfg2, InputShape("x", 128, 4, "train"))["flops"] > \
        analytic_totals(cfg, InputShape("x", 128, 4, "train"))["flops"]


def test_decode_cost_is_cache_bound():
    """Decode bytes are dominated by the KV cache, not params alone."""
    from repro.configs import get_config
    cfg = get_config("qwen3-8b")
    t = analytic_totals(cfg, InputShape("decode_32k", 32768, 128, "decode"))
    param_bytes = cfg.param_count() * 2
    assert t["bytes"] > param_bytes  # cache read adds on top
