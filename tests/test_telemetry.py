"""repro.telemetry: sketch accuracy/mergeability, windowed rollups,
metrics registry + export, sinks, and trace_mode="streaming" parity
with the dense trace on both simulate() and simulate_cluster()."""
import dataclasses
import io
import json
import math
import warnings

import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.cluster import simulate_cluster
from repro.core import generate_events, simulate, synthetic_database
from repro.telemetry import (
    CallbackSink,
    Histogram,
    JsonLinesSink,
    MemorySink,
    MetricsRegistry,
    MetricsSink,
    QuantileSketch,
    StreamingCollector,
    StreamingTrace,
    ThresholdSink,
    WindowedRollup,
    export_path_format,
    render_export,
)
from repro.telemetry.sketch import _percentile_sorted

PCTS = (0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0)


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


@pytest.fixture(scope="module")
def cap(db):
    return simulate(db, 4, scheduler="none", events=[],
                    num_queries=10).peak_throughput


@pytest.fixture(scope="module")
def service(db):
    t = simulate(db, 4, scheduler="none", events=[], num_queries=10)
    return float(t.service_latencies[-1])


class ShedAll:
    """Admission policy that sheds every arrival (zero-admitted runs)."""

    admits_all = False
    slo = 1.0

    def admit(self, view):
        return False

    def reset(self):
        pass


# ---------------------------------------------------------------------------
# QuantileSketch
# ---------------------------------------------------------------------------

def test_sketch_exact_below_buffer():
    rng = np.random.default_rng(0)
    values = rng.lognormal(0.0, 1.0, size=1000)
    sk = QuantileSketch()
    sk.add(values[:400])
    sk.add(values[400:])
    for pct in PCTS:
        assert sk.percentile(pct) == np.percentile(values, pct)
    assert sk.n == 1000
    assert sk.min == values.min() and sk.max == values.max()
    assert sk.sum == pytest.approx(values.sum(), rel=1e-12)
    assert sk.mean == pytest.approx(values.mean(), rel=1e-12)


def test_sketch_accuracy_compressed():
    rng = np.random.default_rng(1)
    values = rng.lognormal(0.0, 1.5, size=200_000)
    sk = QuantileSketch()
    for chunk in np.array_split(values, 37):
        sk.add(chunk)
    assert sk.n == values.size
    for pct, tol in ((50.0, 0.005), (90.0, 0.005), (99.0, 0.01)):
        exact = np.percentile(values, pct)
        assert abs(sk.percentile(pct) - exact) / exact < tol
    # Extremes stay exact: the sketch tracks min/max separately.
    assert sk.percentile(0.0) == values.min()
    assert sk.percentile(100.0) == values.max()


def test_sketch_merged_matches_whole():
    rng = np.random.default_rng(2)
    values = rng.lognormal(0.0, 1.0, size=200_000)
    shards = [QuantileSketch() for _ in range(4)]
    for shard, chunk in zip(shards, np.array_split(values, 4)):
        shard.add(chunk)
    merged = QuantileSketch.merged(shards)
    assert merged.n == values.size
    assert merged.min == values.min() and merged.max == values.max()
    for pct in (50.0, 99.0):
        exact = np.percentile(values, pct)
        assert abs(merged.percentile(pct) - exact) / exact < 0.01
    # Merging must not mutate the shards.
    assert shards[0].n == values.size // 4


def test_sketch_deterministic():
    rng = np.random.default_rng(3)
    values = rng.exponential(2.0, size=50_000)
    a, b = QuantileSketch(), QuantileSketch()
    for chunk in np.array_split(values, 11):
        a.add(chunk)
        b.add(chunk)
    for pct in PCTS:
        assert a.percentile(pct) == b.percentile(pct)


def test_sketch_empty_and_cdf():
    sk = QuantileSketch()
    assert sk.n == 0 and len(sk) == 0
    assert math.isnan(sk.quantile(0.5))
    assert math.isnan(sk.mean)
    rng = np.random.default_rng(4)
    values = rng.normal(10.0, 2.0, size=30_000)
    sk.add(values)
    exact = float((values <= 10.0).mean())
    assert abs(sk.cdf(10.0) - exact) < 0.01
    assert sk.cdf(values.min() - 1.0) == 0.0
    assert sk.cdf(values.max() + 1.0) == 1.0
    xs = np.linspace(values.min(), values.max(), 50)
    cdf = [sk.cdf(float(x)) for x in xs]
    assert all(a <= b + 1e-12 for a, b in zip(cdf, cdf[1:]))


def test_sketch_copy_independent():
    sk = QuantileSketch()
    sk.add(np.arange(100.0))
    cp = sk.copy()
    cp.add(np.full(100, 1e6))
    assert sk.n == 100 and cp.n == 200
    assert sk.max == 99.0 and cp.max == 1e6


def test_sketch_memory_bounded():
    rng = np.random.default_rng(5)
    sk = QuantileSketch()
    for _ in range(50):
        sk.add(rng.lognormal(0.0, 1.0, size=10_000))
    assert sk.n == 500_000
    # Centroids + buffer stay bounded regardless of n.
    assert sk._means.size <= 2 * sk.compression
    assert sk._buffered <= sk.buffer_size


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=1e-3, max_value=1e6,
                           allow_nan=False, allow_infinity=False),
                min_size=1, max_size=500))
def test_sketch_exact_path_property(values):
    values = np.asarray(values, dtype=np.float64)
    sk = QuantileSketch()
    sk.add(values)
    for pct in (50.0, 99.0):
        assert sk.percentile(pct) == np.percentile(values, pct)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=1e-3, max_value=1e6,
                           allow_nan=False, allow_infinity=False),
                min_size=2, max_size=500),
       st.integers(min_value=1, max_value=499))
def test_sketch_merge_property(values, cut):
    values = np.asarray(values, dtype=np.float64)
    cut = min(cut, values.size - 1)
    a, b = QuantileSketch(), QuantileSketch()
    a.add(values[:cut])
    b.add(values[cut:])
    merged = QuantileSketch.merged([a, b])
    # Both shards and the merge stay under the exact buffer, so the
    # merged sketch must reproduce numpy's percentiles bit-exactly.
    for pct in (50.0, 99.0):
        assert merged.percentile(pct) == np.percentile(values, pct)


def test_percentile_sorted_matches_numpy():
    rng = np.random.default_rng(6)
    for _ in range(25):
        values = rng.lognormal(0.0, 1.0, size=rng.integers(1, 400))
        s = np.sort(values)
        for pct in PCTS:
            assert _percentile_sorted(s, pct) == np.percentile(values, pct)
    assert math.isnan(_percentile_sorted(np.empty(0), 50.0))


# ---------------------------------------------------------------------------
# WindowedRollup
# ---------------------------------------------------------------------------

def test_rollup_conserves_counts_under_collapse():
    rng = np.random.default_rng(7)
    roll = WindowedRollup(width=1.0, max_windows=16)
    times = np.sort(rng.uniform(0.0, 5000.0, size=10_000))
    lats = rng.exponential(1.0, size=10_000)
    for t_chunk, l_chunk in zip(np.array_split(times, 13),
                                np.array_split(lats, 13)):
        roll.observe_arrivals(t_chunk)
        roll.observe_completions(t_chunk, l_chunk)
    assert roll.num_windows <= 16
    assert roll.arrivals.sum() == 10_000
    assert roll.completions.sum() == 10_000
    assert roll.latency_sum.sum() == pytest.approx(lats.sum(), rel=1e-9)
    assert roll.latency_max.max() == pytest.approx(lats.max())
    edges = roll.edges()
    assert edges.size == roll.num_windows
    assert edges[0] <= times[0] and edges[-1] <= times[-1]


def test_rollup_merge_conserves():
    rng = np.random.default_rng(8)
    a, b = WindowedRollup(width=2.0), WindowedRollup(width=3.0)
    ta = np.sort(rng.uniform(0.0, 100.0, size=500))
    tb = np.sort(rng.uniform(50.0, 400.0, size=700))
    a.observe_arrivals(ta)
    b.observe_arrivals(tb)
    b.observe_shed(tb[:100])
    merged = a.merge(b)
    assert merged is a  # documented in-place fold
    assert merged.arrivals.sum() == 1200
    assert merged.shed.sum() == 100
    assert b.arrivals.sum() == 700  # the folded operand is untouched


def test_rollup_rates():
    roll = WindowedRollup(width=10.0)
    roll.observe_arrivals(np.array([1.0, 2.0, 11.0, 12.0, 13.0]))
    starts, offered, completed = roll.rates()
    assert starts.size == offered.size == completed.size
    assert offered[0] == pytest.approx(0.2)   # 2 arrivals / width 10
    assert offered[1] == pytest.approx(0.3)
    assert completed.sum() == 0.0


# ---------------------------------------------------------------------------
# Metrics registry + export
# ---------------------------------------------------------------------------

def test_registry_basics():
    reg = MetricsRegistry(namespace="repro")
    c = reg.counter("queries_total", "queries seen")
    c.inc()
    c.inc(4)
    g = reg.gauge("queue_depth")
    g.set(7.0)
    s = reg.summary("latency_seconds")
    s.observe(np.arange(1.0, 101.0))
    assert c.value == 5.0
    assert g.value == 7.0
    assert s.count == 100
    assert s.quantile(0.5) == np.percentile(np.arange(1.0, 101.0), 50)
    # get-or-create returns the same object; kind mismatch raises.
    assert reg.counter("queries_total") is c
    with pytest.raises(TypeError):
        reg.gauge("queries_total")
    assert "queries_total" in reg
    snap = reg.snapshot()
    assert snap["repro_queries_total"] == 5.0
    assert snap["repro_latency_seconds"]["count"] == 100


def test_registry_merge():
    a, b = MetricsRegistry("n"), MetricsRegistry("n")
    a.counter("x").inc(2)
    b.counter("x").inc(3)
    a.gauge("g").set(1.0)
    b.gauge("g").set(9.0)
    a.summary("s").observe([1.0, 2.0])
    b.summary("s").observe([3.0, 4.0])
    m = a.merge(b)
    assert m.counter("x").value == 5.0
    assert m.gauge("g").value == 9.0  # last-writer wins
    assert m.summary("s").count == 4


def test_prometheus_and_json_export():
    reg = MetricsRegistry(namespace="repro")
    reg.counter("queries_total", "total queries").inc(3)
    reg.gauge("depth").set(float("nan"))
    reg.summary("latency_seconds").observe([1.0, 2.0, 3.0, 4.0])
    text = reg.prometheus()
    assert "# TYPE repro_queries_total counter" in text
    assert "repro_queries_total 3.0" in text
    assert "repro_depth NaN" in text
    assert 'repro_latency_seconds{quantile="0.99"}' in text
    assert "repro_latency_seconds_count 4" in text
    assert text == render_export(reg, "prometheus")
    decoded = json.loads(render_export(reg, "json"))
    assert decoded["repro_queries_total"] == 3.0
    with pytest.raises(ValueError):
        render_export(reg, "xml")
    assert export_path_format("m.prom") == ("m.prom", "prometheus")
    assert export_path_format("m.txt") == ("m.txt", "prometheus")
    assert export_path_format("m.json") == ("m.json", "json")


def test_invalid_metric_name_rejected():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("bad name!")


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------

def test_sinks(tmp_path):
    mem = MemorySink()
    assert isinstance(mem, MetricsSink)
    assert mem.last is None
    mem.emit({"a": 1})
    mem.emit({"a": 2})
    assert len(mem) == 2 and mem.last == {"a": 2}

    seen = []
    cb = CallbackSink(seen.append)
    cb.emit({"b": 3})
    assert seen == [{"b": 3}]

    path = tmp_path / "metrics.jsonl"
    with JsonLinesSink(str(path)) as sink:
        sink.emit({"c": 4})
        sink.emit({"c": 5})
    lines = path.read_text().splitlines()
    assert [json.loads(ln)["c"] for ln in lines] == [4, 5]

    buf = io.StringIO()
    JsonLinesSink(buf).emit({"d": 6})
    assert json.loads(buf.getvalue())["d"] == 6


def test_histogram_metric():
    reg = MetricsRegistry("t")
    h = reg.histogram("latency_seconds", "per-query latency",
                      buckets=(0.1, 1.0, 10.0))
    assert isinstance(h, Histogram)
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    cum = h.cumulative()
    assert cum["0.1"] == 1 and cum["1"] == 2 and cum["10"] == 3
    assert cum["+Inf"] == 4 and h.count == 4
    assert h.sum == pytest.approx(55.55)
    snap = reg.snapshot()["t_latency_seconds"]
    assert snap["count"] == 4 and "buckets" in snap
    # merge conserves counts bucket-by-bucket
    other = MetricsRegistry("t")
    other.histogram("latency_seconds", buckets=(0.1, 1.0, 10.0)) \
         .observe(0.5)
    reg.merge(other)
    assert h.cumulative()["1"] == 3 and h.count == 5
    assert "t_latency_seconds_bucket" in reg.prometheus()


def test_threshold_sink_fires_with_hysteresis():
    hits = []
    sink = ThresholdSink()
    sink.add_rule("avail", 0.99, above=False, clear=0.995,
                  callback=hits.append)
    for v in (1.0, 0.98, 0.97, 0.992, 0.996, 0.98):
        sink.emit({"avail": v})
    # fires entering the breach, re-arms only after clearing 0.995
    assert [i["snapshot_index"] for i in sink.incidents] == [1, 5]
    assert [i["value"] for i in sink.incidents] == [0.98, 0.98]
    assert hits == sink.incidents
    assert isinstance(sink, MetricsSink)


def test_threshold_sink_quantile_rule_and_validation():
    sink = ThresholdSink()
    sink.add_rule("lat", 1.0, quantile="0.99", clear=0.8)
    summary = {"count": 1, "sum": 1.0, "quantiles": {"0.99": 2.0}}
    sink.emit({"lat": summary})
    assert sink.incidents[0]["rule"] == "lat{q=0.99}"
    sink.emit({"lat": {"quantiles": {"0.99": float("nan")}}})
    sink.emit({})                           # missing metric: no signal
    assert len(sink.incidents) == 1
    with pytest.raises(ValueError, match="never reset"):
        sink.add_rule("x", 1.0, clear=2.0)


# ---------------------------------------------------------------------------
# StreamingCollector / StreamingTrace vs the dense trace
# ---------------------------------------------------------------------------

def _summary_close(dense: dict, stream: dict, p99_tol=0.01):
    assert set(dense) == set(stream)
    for key in ("num_shed", "shed_rate", "rebalances", "slo_latency_s",
                "offered_load_qps", "achieved_load_qps", "mean_latency_s"):
        x, y = float(dense[key]), float(stream[key])
        assert (math.isnan(x) and math.isnan(y)) or x == pytest.approx(
            y, rel=1e-9), key
    for key, tol in (("p99_latency_s", p99_tol), ("p50_latency_s", 0.02),
                     ("goodput_qps", 0.01)):
        x, y = float(dense[key]), float(stream[key])
        if math.isnan(x):
            assert math.isnan(y), key
        else:
            assert abs(x - y) <= tol * max(abs(x), 1e-12), key
    assert abs(float(dense["slo_attainment"])
               - float(stream["slo_attainment"])) <= 0.005


def test_streaming_simulate_parity(db, cap, service):
    kw = dict(
        scheduler="none", events=[], num_queries=8000,
        workload="bursty",
        workload_kwargs=dict(burst_rate=3.0 * cap, base_rate=0.5 * cap,
                             mean_burst=2000.0 / cap,
                             mean_gap=1000.0 / cap, seed=7),
        admission="slo_shed",
        admission_kwargs=dict(slo=3.0 * service))
    dense = simulate(db, 4, **kw)
    sink = MemorySink()
    stream = simulate(db, 4, trace_mode="streaming", metrics_sink=sink,
                      sink_interval=1000, **kw)
    assert isinstance(stream, StreamingTrace)
    _summary_close(dense.summary(), stream.summary())
    assert stream.num_shed == dense.num_shed
    assert len(sink) >= 2
    # Snapshots carry the registry counters, not dense arrays.
    assert sink.last["repro_queries_admitted_total"] == stream.num_admitted
    assert sink.last["repro_queries_shed_total"] == stream.num_shed
    # Flat-memory contract: no dense per-query arrays on the trace.
    assert not hasattr(stream, "latencies")
    assert stream.tail_latency(99) == stream.percentile(99.0)
    prom = stream.prometheus()
    assert "repro_queries_admitted_total" in prom


def test_streaming_cluster_parity(db, cap, service):
    events = [
        dataclasses.replace(ev, replica=2)
        for ev in generate_events(2000, 4, db.num_scenarios, 2, 100, 5)
    ]
    kw = dict(
        scheduler="odin", alpha=10, num_queries=8000, events=events,
        router="odin_aware", workload="bursty",
        workload_kwargs=dict(burst_rate=8.0 * cap, base_rate=1.5 * cap,
                             mean_burst=80.0 / cap, mean_gap=250.0 / cap,
                             seed=6),
        admission="slo_shed", admission_kwargs=dict(slo=3.0 * service),
        autoscaler="load_profile")
    dense = simulate_cluster(db, 4, 4, **kw)
    sink = MemorySink()
    stream = simulate_cluster(db, 4, 4, trace_mode="streaming",
                              metrics_sink=sink, sink_interval=1000, **kw)
    _summary_close(dense.summary(), stream.summary(), p99_tol=0.02)
    assert stream.num_shed == dense.num_shed
    assert np.array_equal(stream.replica_counts, dense.replica_counts)
    assert stream.mean_active_replicas == pytest.approx(
        dense.summary()["mean_active_replicas"])
    assert len(sink) >= 2
    # rows() keeps the per-replica + fleet reporting schema of the
    # dense trace.
    drows, srows = dense.rows(), stream.rows()
    assert len(drows) == len(srows) == 5
    for dr, sr in zip(drows, srows):
        assert set(dr) == set(sr)
        assert dr["scope"] == sr["scope"]
        assert dr["queries"] == sr["queries"]


def test_dense_with_sink_stays_bit_identical(db, cap):
    kw = dict(scheduler="none", events=[], num_queries=3000,
              workload="poisson",
              workload_kwargs=dict(rate=0.9 * cap, seed=0))
    plain = simulate(db, 4, **kw)
    sink = MemorySink()
    observed = simulate(db, 4, metrics_sink=sink, sink_interval=500, **kw)
    assert len(sink) >= 2
    sp, so = plain.summary(), observed.summary()
    assert set(sp) == set(so)
    for key in sp:
        x, y = float(sp[key]), float(so[key])
        assert (math.isnan(x) and math.isnan(y)) or x == y, key
    assert np.array_equal(plain.latencies, observed.latencies)


def test_zero_admitted_summary_nan_safe(db, cap):
    kw = dict(scheduler="none", events=[], num_queries=50,
              workload="poisson",
              workload_kwargs=dict(rate=0.5 * cap, seed=0),
              admission=ShedAll())
    for mode in ("dense", "streaming"):
        t = simulate(db, 4, trace_mode=mode, **kw)
        assert t.num_shed == 50
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            s = t.summary()
        assert math.isnan(s["p99_latency_s"])
        assert math.isnan(s["p50_latency_s"])
        assert s["num_shed"] == 50
        assert s["shed_rate"] == 1.0


def test_dense_trace_percentile_cached(db, cap):
    t = simulate(db, 4, scheduler="none", events=[], num_queries=2000,
                 workload="poisson",
                 workload_kwargs=dict(rate=0.9 * cap, seed=1))
    for pct in (50.0, 99.0):
        expected = float(np.percentile(t.latencies, pct))
        assert t.percentile(pct) == expected
        assert t.percentile(pct) == expected  # cached second call
    assert t.percentile(99.0, "queue_delays") == float(
        np.percentile(t.queue_delays, 99.0))
    assert t.tail_latency(99) == t.percentile(99.0)


def test_streaming_trace_modes_and_errors(db):
    with pytest.raises(ValueError):
        simulate(db, 4, scheduler="none", events=[], num_queries=10,
                 trace_mode="sparse")
    t = simulate(db, 4, scheduler="none", events=[], num_queries=200,
                 trace_mode="streaming")
    with pytest.raises(ValueError):
        t.slo_violations(0.9, reference="resource_constrained")
    assert t.slo_violations(0.9) in (0.0, 1.0) or 0.0 <= t.slo_violations(0.9) <= 1.0


def test_streaming_collector_absorb():
    a = StreamingCollector(slo=10.0)
    b = StreamingCollector(slo=10.0)
    rng = np.random.default_rng(9)
    for col, seed in ((a, 0), (b, 1)):
        lat = rng.exponential(5.0, size=1000)
        times = np.sort(rng.uniform(0.0, 100.0, size=1000))
        col.observe_chunk(lat, lat * 0.5, lat * 0.5,
                          np.full(1000, 2.0), np.zeros(1000, dtype=bool),
                          times, times + lat,
                          np.zeros(1000))
    total = StreamingCollector(slo=10.0)
    total.absorb(a).absorb(b)
    assert total.num_admitted == 2000
    assert total.latency.n == 2000
    merged = QuantileSketch.merged([a.latency, b.latency])
    assert total.latency.percentile(99.0) == merged.percentile(99.0)
