"""Fault injection + recovery machinery (docs/FAULTS.md).

Covers the plan/spec layer (parsing, validation, determinism), the
retry/backoff and circuit-breaker units, the simulator integrations
(single pipeline and fleet, dense and chunked), and the live-engine
crash/recover acceptance path.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.cluster import simulate_cluster
from repro.core import simulate, synthetic_database
from repro.faults import (
    FaultEvent,
    FaultPlan,
    HealthTracker,
    RetrySpec,
    parse_fault_spec,
    periodic_crashes,
    resolve_faults,
    resolve_retries,
)
from repro.faults.health import CLOSED, HALF_OPEN, OPEN


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


SIM_KW = dict(num_queries=300, freq_period=2, duration=100, seed=0)


def _same_summary(a: dict, b: dict) -> bool:
    return all(a[k] == b[k]
               or (isinstance(a[k], float) and math.isnan(a[k])
                   and math.isnan(b[k]))
               for k in a) and a.keys() == b.keys()


# -- plans + specs -----------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meltdown", 0, 10)
    with pytest.raises(ValueError, match="duration"):
        FaultEvent("crash", 0, 0)
    with pytest.raises(ValueError, match="probability"):
        FaultEvent("flaky", 0, 10, p=1.5)
    with pytest.raises(ValueError, match="factor"):
        FaultEvent("slowdown", 0, 10, factor=0.0)
    ev = FaultEvent("crash", 5, 10)
    assert ev.end == 15 and ev.active_at(5) and not ev.active_at(15)


def test_parse_fault_spec_grammar():
    plan = parse_fault_spec(
        "crash@200+100:r=0,flaky@0+1000:p=0.05,hang@400+20:s=0.5:r=1")
    kinds = [e.kind for e in plan.events]
    assert sorted(kinds) == ["crash", "flaky", "hang"]
    hang = next(e for e in plan.events if e.kind == "hang")
    assert hang.stall == 0.5 and hang.replica == 1
    with pytest.raises(ValueError, match="expected"):
        parse_fault_spec("crash200+100")
    with pytest.raises(ValueError, match="unknown fault option"):
        parse_fault_spec("crash@0+10:z=3")


def test_resolve_faults_forms():
    assert resolve_faults(None) is None
    plan = FaultPlan([FaultEvent("crash", 0, 10)])
    assert resolve_faults(plan) is plan
    # list mixing FaultEvent objects, spec strings and bare tuples
    mixed = resolve_faults([FaultEvent("crash", 0, 10),
                            "flaky@5+10:p=0.2",
                            ("slowdown", 3.0, 4.0)])
    assert sorted(e.kind for e in mixed.events) == \
        ["crash", "flaky", "slowdown"]
    with pytest.raises(TypeError):
        resolve_faults(42)


def test_periodic_crashes_rotates_replicas():
    plan = periodic_crashes(1000.0, period=200.0, duration=50.0,
                            num_replicas=3, time_indexed=True)
    assert plan.time_indexed
    assert [e.replica for e in plan.events] == [0, 1, 2, 0]
    assert all(e.kind == "crash" for e in plan.events)
    assert plan.for_replica(1).events == [plan.events[1]]


# -- retry / backoff ---------------------------------------------------------

def test_retry_spec_backoff_and_jitter():
    spec = RetrySpec(max_retries=3, backoff=0.5, multiplier=2.0)
    assert [spec.delay(7, a) for a in range(3)] == [0.5, 1.0, 2.0]
    jit = RetrySpec(backoff=0.5, jitter=0.4, seed=3)
    d = jit.delay(11, 1)
    assert d == jit.delay(11, 1)           # deterministic redraw
    assert 1.0 <= d <= 1.4                 # base * (1 + jitter*[0,1))
    assert jit.delay(12, 1) != d           # queries de-synchronize


def test_resolve_retries_forms():
    assert resolve_retries(None) is None
    assert resolve_retries(2).max_retries == 2
    assert resolve_retries(dict(max_retries=1, timeout=3.0)).timeout == 3.0
    spec = RetrySpec()
    assert resolve_retries(spec) is spec
    with pytest.raises(TypeError):
        resolve_retries(True)
    with pytest.raises(ValueError):
        RetrySpec(max_retries=-1)
    with pytest.raises(ValueError):
        RetrySpec(timeout=0.0)


# -- circuit breaker ---------------------------------------------------------

def test_breaker_opens_on_streak_and_probes_closed():
    hb = HealthTracker(2, failure_threshold=2, cooldown=10.0)
    assert hb.state(0) == CLOSED and hb.healthy(0, 0.0)
    hb.record_failure(0, 1.0)
    assert hb.state(0) == CLOSED           # streak 1 < threshold
    hb.record_failure(0, 2.0)
    assert hb.state(0) == OPEN
    assert not hb.healthy(0, 5.0)          # cooling down
    assert hb.ready_at(0) == 12.0
    assert hb.healthy(0, 12.0)             # expiry -> half-open probe
    assert hb.state(0) == HALF_OPEN
    assert hb.take_rewarm(0) and not hb.take_rewarm(0)   # one-shot
    hb.record_success(0, 13.0)
    assert hb.state(0) == CLOSED
    assert hb.downtime[0] == pytest.approx(11.0)
    assert hb.state(1) == CLOSED           # untouched replica


def test_breaker_known_downtime_and_reopen():
    hb = HealthTracker(1, failure_threshold=3, cooldown=1.0)
    # a known recovery time opens immediately, ignoring the streak
    hb.record_failure(0, 5.0, until=50.0)
    assert hb.state(0) == OPEN and hb.ready_at(0) == 50.0
    assert hb.healthy(0, 50.0) and hb.state(0) == HALF_OPEN
    hb.record_failure(0, 51.0)             # failed probe -> re-open
    assert hb.state(0) == OPEN
    down = hb.finalize(60.0)
    assert down[0] == pytest.approx(55.0)  # 45 + 9, open time only


# -- simulator: single pipeline ----------------------------------------------

@pytest.mark.parametrize("spec", ["crash@60+40", "hang@50+30:s=50",
                                  "slowdown@40+60:f=3",
                                  "flaky@30+120:p=0.5"])
def test_seeded_determinism_per_kind(db, spec):
    a = simulate(db, 4, scheduler="odin", faults=spec, retries=2, **SIM_KW)
    b = simulate(db, 4, scheduler="odin", faults=spec, retries=2, **SIM_KW)
    assert np.array_equal(a.latencies, b.latencies)
    assert np.array_equal(a.throughputs, b.throughputs)
    assert _same_summary(a.summary(), b.summary())


def test_flaky_draws_depend_on_plan_seed(db):
    runs = [simulate(db, 4, scheduler="none", retries=2,
                     faults=FaultPlan([FaultEvent("flaky", 30, 120, p=0.5)],
                                      seed=s), **SIM_KW)
            for s in (1, 2)]
    assert not np.array_equal(runs[0].latencies, runs[1].latencies)


def test_no_faults_bit_identity(db):
    """An empty fault plan + a retry budget must not perturb a run."""
    base = simulate(db, 4, scheduler="odin", **SIM_KW)
    wrapped = simulate(db, 4, scheduler="odin", retries=3,
                       faults=FaultPlan(events=[]), **SIM_KW)
    assert np.array_equal(base.latencies, wrapped.latencies)
    assert np.array_equal(base.throughputs, wrapped.throughputs)
    assert base.configs_trace == wrapped.configs_trace
    s = wrapped.summary()
    assert s["num_failed"] == 0 and s["num_retried"] == 0
    assert s["availability"] == 1.0 and s["wasted_work_frac"] == 0.0


@pytest.mark.parametrize("scheduler", ["odin", "lls", "none"])
def test_chunked_equals_scalar_with_faults(db, scheduler):
    kw = dict(faults=["flaky@50+100:p=0.3", "slowdown@120+60:f=2"],
              retries=2, **SIM_KW)
    a = simulate(db, 4, scheduler=scheduler, chunking=False, **kw)
    b = simulate(db, 4, scheduler=scheduler, chunking=True, **kw)
    assert np.array_equal(a.latencies, b.latencies)
    assert np.array_equal(a.throughputs, b.throughputs)
    assert a.configs_trace == b.configs_trace
    assert _same_summary(a.summary(), b.summary())


def test_flaky_retries_recover_queries(db):
    t = simulate(db, 4, scheduler="none", faults="flaky@50+100:p=0.4",
                 retries=3, **SIM_KW)
    s = t.summary()
    assert s["num_retried"] > 0
    assert 0.9 < s["availability"] <= 1.0
    # availability is completed / admitted
    admitted = SIM_KW["num_queries"]
    assert s["availability"] == pytest.approx(
        (admitted - s["num_failed"]) / admitted)


def test_hang_timeout_converts_stall_to_retry(db):
    timed = simulate(db, 4, scheduler="none", faults="hang@50+80:s=500",
                     retries=dict(max_retries=2, timeout=200.0), **SIM_KW)
    free = simulate(db, 4, scheduler="none", faults="hang@50+80:s=500",
                    **SIM_KW)
    st, sf = timed.summary(), free.summary()
    assert st["num_retried"] > 0 and st["wasted_work_frac"] > 0.0
    # without a timeout the stall surfaces as latency, not failures
    assert sf["num_retried"] == 0 and sf["num_failed"] == 0
    assert sf["p99_latency_s"] > st["p99_latency_s"]


# -- fleet -------------------------------------------------------------------

def test_cluster_no_faults_bit_identity_with_retries(db):
    kw = dict(scheduler="odin", num_queries=200, workload="poisson",
              workload_kwargs=dict(rate=0.01, seed=3),
              router="least_outstanding")
    base = simulate_cluster(db, 3, 2, **kw)
    armed = simulate_cluster(db, 3, 2, retries=2, hedge_after=None, **kw)
    assert np.array_equal(base.assignments, armed.assignments)
    assert np.array_equal(base.fleet.latencies, armed.fleet.latencies)
    assert _same_summary(base.summary(), armed.summary())


def test_cluster_summaries_grow_fault_keys(db):
    keys = ("num_failed", "num_retried", "num_hedged", "availability",
            "wasted_work_frac", "downtime_s")
    kw = dict(scheduler="none", num_queries=60)
    for mode in ("dense", "streaming"):
        s = simulate_cluster(db, 3, 2, trace_mode=mode, **kw).summary()
        assert all(k in s for k in keys), mode
    t = simulate(db, 4, scheduler="none", num_queries=60)
    assert all(k in t.summary() for k in keys)


def test_time_indexed_crash_recovery(db):
    """The crashed replica rejoins the fleet after its window: the
    breaker opens on the known outage, holds until the recovery time,
    then a successful probe closes it and later arrivals land there."""
    plan = FaultPlan([FaultEvent("crash", 2000.0, 4000.0, replica=1)],
                     seed=0, time_indexed=True)
    kw = dict(scheduler="none", num_queries=250, workload="poisson",
              workload_kwargs=dict(rate=0.01, seed=3),
              router="least_outstanding", faults=plan,
              retries=dict(max_retries=3, backoff=50.0),
              health_kwargs=dict(failure_threshold=1, cooldown=500.0))
    ct = simulate_cluster(db, 3, 2, **kw)
    s = ct.summary()
    assert s["availability"] == 1.0
    assert s["num_retried"] >= 1
    assert s["downtime_s"] >= 4000.0            # at least the window
    post = (ct.assignments == 1) & (ct.fleet.arrival_times > 8000.0)
    assert post.sum() > 0                       # replica 1 rejoined
    rerun = simulate_cluster(db, 3, 2, **kw)
    assert np.array_equal(ct.assignments, rerun.assignments)


def test_hedging_first_wins_and_charges_loser(db):
    """One permanently slow replica: hedged dispatches run on the fast
    peer (first projected finisher wins), the loser's reserved
    occupancy is charged as wasted work, and the tail collapses."""
    plan = FaultPlan([FaultEvent("slowdown", 0.0, 1e9, replica=0,
                                 factor=5.0)], seed=0)
    kw = dict(scheduler="none", num_queries=150, workload="poisson",
              workload_kwargs=dict(rate=0.008, seed=2),
              router="round_robin", faults=plan, retries=1)
    hedged = simulate_cluster(db, 3, 2, hedge_after=50.0, **kw)
    straight = simulate_cluster(db, 3, 2, **kw)
    sh, ss = hedged.summary(), straight.summary()
    assert sh["num_hedged"] > 0
    assert sh["wasted_work_frac"] > 0.0 and ss["wasted_work_frac"] == 0.0
    assert sh["availability"] == 1.0
    assert sh["p99_latency_s"] < ss["p99_latency_s"]


@pytest.mark.parametrize("mode", ["wait", "shed"])
def test_all_replicas_unhealthy_no_deadlock(db, mode):
    """A fleet-wide crash window: both replicas' breakers open at once.
    ``wait`` holds arrivals for the earliest recovery (in-window
    arrivals stay doomed — windows anchor on the arrival clock — and
    fail after their budget); ``shed`` turns them away up front.
    Either way the run must terminate, deterministically."""
    plan = FaultPlan([FaultEvent("crash", 3000.0, 2000.0)],
                     seed=0, time_indexed=True)
    kw = dict(scheduler="none", num_queries=120, workload="poisson",
              workload_kwargs=dict(rate=0.012, seed=5),
              router="least_outstanding", faults=plan,
              retries=dict(max_retries=5, backoff=100.0),
              health_kwargs=dict(failure_threshold=1, cooldown=400.0),
              when_all_unhealthy=mode)
    ct = simulate_cluster(db, 3, 2, **kw)
    s = ct.summary()
    served = int(ct.replica_counts.sum())
    assert served == 120 - int(s["num_failed"]) - int(s["num_shed"])
    if mode == "shed":
        assert s["num_shed"] > 0
    else:
        assert s["num_shed"] == 0 and s["num_failed"] > 0
    rerun = simulate_cluster(db, 3, 2, **kw)
    assert _same_summary(s, rerun.summary())


def test_hedging_composes_with_fleet_rebatching(db):
    """Hedging + rebatching (docs/FAULTS.md "Hedged batched dispatch"):
    whole buffered dispatches are duplicated on the least-loaded healthy
    peer — hedged members are counted, the loser's reserved span is
    charged as wasted work, and the composition stays deterministic."""
    plan = FaultPlan([FaultEvent("slowdown", 0.0, 1e9, replica=0,
                                 factor=5.0)], seed=0)
    kw = dict(scheduler="none", num_queries=150, workload="poisson",
              workload_kwargs=dict(rate=0.008, seed=2),
              router="round_robin", faults=plan, retries=1, max_batch=4)
    hedged = simulate_cluster(db, 3, 2, hedge_after=50.0, **kw)
    straight = simulate_cluster(db, 3, 2, **kw)
    sh, ss = hedged.summary(), straight.summary()
    assert sh["num_hedged"] > 0
    assert sh["wasted_work_frac"] > 0.0 and ss["wasted_work_frac"] == 0.0
    assert sh["availability"] == 1.0
    assert sh["p99_latency_s"] < ss["p99_latency_s"]
    rerun = simulate_cluster(db, 3, 2, hedge_after=50.0, **kw)
    assert _same_summary(sh, rerun.summary())


@pytest.mark.parametrize("policy", ["resplit", "subset", "all"])
def test_batch_retry_policies(db, policy):
    kw = dict(scheduler="none", num_queries=120, workload="poisson",
              workload_kwargs=dict(rate=20.0, seed=11), max_batch=4,
              faults="flaky@0+100000:p=0.06",
              retries=dict(max_retries=3, batch_policy=policy))
    ct = simulate_cluster(db, 3, 2, **kw)
    s = ct.summary()
    # every fleet arrival lands in exactly one ledger state
    n_ok = int((ct.assignments >= 0).sum())
    n_fail = int((ct.assignments == -2).sum())
    assert n_ok + n_fail == 120
    assert s["num_retried"] > 0
    # per-replica row counts agree with the assignment ledger
    for r, tr in enumerate(ct.replicas):
        assert len(tr.latencies) == int((ct.assignments == r).sum())
    rerun = simulate_cluster(db, 3, 2, **kw)
    assert _same_summary(s, rerun.summary())


def test_when_all_unhealthy_validated(db):
    with pytest.raises(ValueError, match="when_all_unhealthy"):
        simulate_cluster(db, 3, 2, scheduler="none", num_queries=20,
                         retries=1, when_all_unhealthy="explode")


# -- live acceptance ---------------------------------------------------------

@pytest.fixture(scope="module")
def live_setup():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=4)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 32)))
               for _ in range(36)]
    engines = [ServingEngine(cfg, params, num_eps=4, scheduler="none")
               for _ in range(2)]
    for eng in engines:
        eng.executor.warmup(1, 32)
    probe = engines[0].serve(queries[:4], lambda q: [1.0] * 4)
    service = float(probe.service_latencies[1:].mean())
    return engines, queries, service


def test_live_crash_recover_acceptance(live_setup):
    """Acceptance (ISSUE): live fleet, replica 1 crashes mid-run and
    recovers — retries + health routing carry every query, the
    recovering replica re-warms (warm_buckets) before taking traffic,
    and it serves again after the window."""
    from repro.cluster import serve_cluster

    engines, queries, service = live_setup
    rate = 0.5 / service                    # fleet-wide arrival rate
    horizon = len(queries) / rate
    plan = FaultPlan([FaultEvent("crash", 0.2 * horizon, 0.3 * horizon,
                                 replica=1)], seed=0, time_indexed=True)

    rewarmed = []
    orig = engines[1].executor.warm_buckets

    def tracking_warm(seqs, max_batch):
        rewarmed.append(list(seqs))
        return orig(seqs, max_batch)

    engines[1].executor.warm_buckets = tracking_warm
    try:
        ct = serve_cluster(
            engines, queries, lambda q: [1.0] * 4,
            workload="poisson", workload_kwargs=dict(rate=rate, seed=4),
            router="least_outstanding", faults=plan,
            retries=dict(max_retries=3, backoff=0.25 * horizon,
                         jitter=0.1),
            health_kwargs=dict(failure_threshold=1,
                               cooldown=0.05 * horizon))
    finally:
        engines[1].executor.warm_buckets = orig

    s = ct.summary()
    assert s["availability"] >= 0.9
    assert s["num_retried"] >= 1
    assert s["downtime_s"] > 0.0
    assert rewarmed and rewarmed[0] == [32]  # re-warm before the probe
    post = (ct.assignments == 1) & \
        (ct.fleet.arrival_times > 0.5 * horizon)
    assert post.sum() > 0                    # replica 1 took traffic again
