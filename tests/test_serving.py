"""Live serving engine: ODIN reacts to physically injected interference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=8)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)))
               for _ in range(40)]
    return cfg, params, queries


def _schedule(q):
    slow = [1.0, 1.0, 1.0, 1.0]
    if 10 <= q < 30:
        slow[1] = 3.0
    return slow


def test_odin_moves_blocks_off_interfered_ep(setup):
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="odin", alpha=3)
    eng.executor.warmup(1, 64)
    m = eng.serve(queries, _schedule)
    assert m.num_rebalances >= 1
    # during the interference episode ODIN sheds blocks from EP 1
    mid_cfgs = [c for c in m.configs[15:30]]
    assert min(c[1] for c in mid_cfgs) < 2
    # every served config conserves blocks
    for c in m.configs:
        assert sum(c) == cfg.num_blocks
    s = m.summary()
    assert s["mean_latency_s"] > 0
    assert np.isfinite(s["mean_throughput_qps"])


def test_static_scheduler_never_rebalances(setup):
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="none")
    eng.executor.warmup(1, 64)
    m = eng.serve(queries[:20], _schedule)
    assert m.num_rebalances == 0
    assert all(c == m.configs[0] for c in m.configs)
