"""Live serving engine: ODIN reacts to physically injected interference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import SimResult, simulate, synthetic_database
from repro.models import Model
from repro.serving import ServeMetrics, ServingEngine
from repro.workloads import PipelineTrace


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=8)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)))
               for _ in range(40)]
    return cfg, params, queries


def _schedule(q):
    slow = [1.0, 1.0, 1.0, 1.0]
    if 10 <= q < 30:
        slow[1] = 3.0
    return slow


def test_odin_moves_blocks_off_interfered_ep(setup):
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="odin", alpha=3)
    eng.executor.warmup(1, 64)
    m = eng.serve(queries, _schedule)
    assert m.num_rebalances >= 1
    # during the interference episode ODIN sheds blocks from EP 1
    mid_cfgs = [c for c in m.configs[15:30]]
    assert min(c[1] for c in mid_cfgs) < 2
    # every served config conserves blocks
    for c in m.configs:
        assert sum(c) == cfg.num_blocks
    s = m.summary()
    assert s["mean_latency_s"] > 0
    assert np.isfinite(s["mean_throughput_qps"])


def test_static_scheduler_never_rebalances(setup):
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="none")
    eng.executor.warmup(1, 64)
    m = eng.serve(queries[:20], _schedule)
    assert m.num_rebalances == 0
    assert all(c == m.configs[0] for c in m.configs)


def test_serve_metrics_summary_parity_with_simulator(setup):
    """One trace type: ServeMetrics summaries carry the identical key
    set — p50 / SLO / queueing included — as SimResult summaries."""
    assert ServeMetrics is PipelineTrace and SimResult is PipelineTrace
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="odin", alpha=3)
    eng.executor.warmup(1, 64)
    live = eng.serve(queries[:12], _schedule).summary()
    sim = simulate(synthetic_database("vgg16", seed=0), 4,
                   scheduler="odin", num_queries=100, freq_period=20,
                   duration=10, seed=0).summary()
    assert set(live.keys()) == set(sim.keys())
    for s in (live, sim):
        assert s["p50_latency_s"] <= s["p99_latency_s"]
        assert 0.0 <= s["slo_violations"] <= 1.0
    # the engine's peak reference comes from its clean block estimates
    assert np.isfinite(live["peak_throughput_qps"])


def test_engine_open_loop_bursty_reports_queueing(setup):
    """Open-loop serving through the same engine: queueing delay is
    accounted separately from measured service latency."""
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="none")
    eng.executor.warmup(1, 64)
    # calibrate the burst to this host: measure one closed-loop query
    probe = eng.serve(queries[:2], lambda q: [1.0] * 4)
    service = float(probe.service_latencies.mean())
    m = eng.serve(queries[:20], lambda q: [1.0] * 4, workload="bursty",
                  workload_kwargs=dict(burst_rate=4.0 / service,
                                       base_rate=0.0,
                                       mean_burst=40 * service,
                                       mean_gap=5 * service, seed=0))
    assert m.workload == "bursty"
    assert np.allclose(m.latencies, m.queue_delays + m.service_latencies)
    assert m.queue_delays.max() > 0           # the burst outran the pipe
    assert np.all(m.service_latencies > 0)
    assert m.offered_load > 0 and np.isfinite(m.achieved_load)
