"""Live serving engine: ODIN reacts to physically injected interference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import SimResult, simulate, synthetic_database
from repro.models import Model
from repro.serving import ServeMetrics, ServingEngine
from repro.workloads import PipelineTrace


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), num_layers=8)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 64)))
               for _ in range(40)]
    return cfg, params, queries


def _schedule(q):
    slow = [1.0, 1.0, 1.0, 1.0]
    if 10 <= q < 30:
        slow[1] = 3.0
    return slow


def test_odin_moves_blocks_off_interfered_ep(setup):
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="odin", alpha=3)
    eng.executor.warmup(1, 64)
    m = eng.serve(queries, _schedule)
    assert m.num_rebalances >= 1
    # during the interference episode ODIN sheds blocks from EP 1
    mid_cfgs = [c for c in m.configs[15:30]]
    assert min(c[1] for c in mid_cfgs) < 2
    # every served config conserves blocks
    for c in m.configs:
        assert sum(c) == cfg.num_blocks
    s = m.summary()
    assert s["mean_latency_s"] > 0
    assert np.isfinite(s["mean_throughput_qps"])


def test_static_scheduler_never_rebalances(setup):
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="none")
    eng.executor.warmup(1, 64)
    m = eng.serve(queries[:20], _schedule)
    assert m.num_rebalances == 0
    assert all(c == m.configs[0] for c in m.configs)


def test_serve_metrics_summary_parity_with_simulator(setup):
    """One trace type: ServeMetrics summaries carry the identical key
    set — p50 / SLO / queueing included — as SimResult summaries."""
    assert ServeMetrics is PipelineTrace and SimResult is PipelineTrace
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="odin", alpha=3)
    eng.executor.warmup(1, 64)
    live = eng.serve(queries[:12], _schedule).summary()
    sim = simulate(synthetic_database("vgg16", seed=0), 4,
                   scheduler="odin", num_queries=100, freq_period=20,
                   duration=10, seed=0).summary()
    assert set(live.keys()) == set(sim.keys())
    for s in (live, sim):
        assert s["p50_latency_s"] <= s["p99_latency_s"]
        assert 0.0 <= s["slo_violations"] <= 1.0
    # the engine's peak reference comes from its clean block estimates
    assert np.isfinite(live["peak_throughput_qps"])


def test_run_batch_matches_stacked_run_query(setup):
    """One stacked dispatch computes the same logits as per-query runs
    (same jitted stage_fn; the batch dim was always a runtime size)."""
    cfg, params, queries = setup
    from repro.pipeline.executor import LocalPipelineExecutor
    ex = LocalPipelineExecutor(cfg, params)
    config = [2, 2, 2, 2]
    singles = [np.asarray(ex.run_query(q, config)[0]) for q in queries[:3]]
    batched, st = ex.run_batch(queries[:3], config)
    assert batched.shape[0] == 3
    assert st.shape == (4,)
    np.testing.assert_allclose(np.asarray(batched),
                               np.concatenate(singles, axis=0),
                               rtol=2e-4, atol=2e-4)
    with pytest.raises(ValueError, match="sequence length"):
        ex.run_batch([queries[0], queries[0][:, :32]], config)
    with pytest.raises(ValueError, match="at least one"):
        ex.run_batch([], config)


def test_batched_serve_accounting_parity(setup):
    """serve(max_batch>1) under a burst: rebalance/trial accounting and
    the config trace match the unbatched run exactly — with frozen
    block-time estimates (estimate_beta=0 after calibration) the
    scheduling layer is deterministic, so the two runs take the
    identical detect -> explore -> commit walk."""
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="odin", alpha=3,
                        estimate_beta=0.3)
    eng.executor.warmup(1, 64)
    probe = eng.serve(queries[:8], lambda q: [1.0] * 4)
    service = float(probe.service_latencies[3:].mean())
    eng.estimate_beta = 0.0        # freeze: deterministic scheduling
    wl = dict(burst_rate=8.0 / service, base_rate=0.3 / service,
              mean_burst=60 * service, mean_gap=15 * service, seed=0)

    def schedule(q):
        slow = [1.0] * 4
        if 12 <= q < 30:
            slow[1] = 3.0
        return slow

    runs = {}
    for mb in (1, 8):
        eng.reset_policy()
        runs[mb] = eng.serve(queries, schedule, workload="bursty",
                             workload_kwargs=wl, max_batch=mb)
    a, b = runs[1], runs[8]
    assert b.num_rebalances == a.num_rebalances
    assert b.total_trials == a.total_trials
    assert b.mitigation_lengths == a.mitigation_lengths
    assert b.configs_trace == a.configs_trace
    assert np.array_equal(b.serial_mask, a.serial_mask)
    assert a.queue_delays.max() > 0 and b.queue_delays.max() > 0
    assert np.allclose(b.latencies, b.queue_delays + b.service_latencies)


def test_batched_serve_lowers_queueing_under_burst(setup):
    """Real stacked batches drain a backlog faster: no-rebalance regime
    (static scheduler) so the whole queue is governed by the admission
    rate, where batching's amortized occupancy gives a wide margin."""
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="none")
    eng.executor.warmup(1, 64)
    probe = eng.serve(queries[:6], lambda q: [1.0] * 4)
    service = float(probe.service_latencies[2:].mean())
    # heavy overload: every arrival lands on a deep backlog
    wl = dict(burst_rate=12.0 / service, base_rate=0.0,
              mean_burst=200 * service, mean_gap=10 * service, seed=0)
    runs = {}
    for mb in (1, 8):
        runs[mb] = eng.serve(queries, lambda q: [1.0] * 4,
                             workload="bursty", workload_kwargs=wl,
                             max_batch=mb)
    a, b = runs[1], runs[8]
    assert a.queue_delays.max() > 0 and b.queue_delays.max() > 0
    # amortization cuts per-query occupancy well below the scalar
    # bottleneck beat; require a real margin, not a timing-noise win
    assert b.mean_queue_delay < 0.85 * a.mean_queue_delay
    assert b.achieved_load > a.achieved_load


def test_admission_slo_shed_holds_tail_live(setup):
    """Acceptance (docs/CONTROL.md): under an overloaded bursty arrival
    stream the live engine with admission="slo_shed" holds
    p99-of-admitted within the SLO while admission="none" violates it.
    Wall-clock times are noisy on shared hosts, so best-of-3 runs (the
    tests/test_cluster_live.py convention) with a margin-5 shed rule:
    the wait budget is half the SLO, leaving several service beats of
    headroom for the admitted query's own measured time."""
    cfg, params, _ = setup
    # Longer queries than the shared set: host stalls are a roughly
    # constant number of milliseconds, so a bigger per-query service
    # time shrinks them relative to the SLO budget.  Frozen estimates
    # (estimate_beta=0, the PR-3 A/B knob) keep the shed threshold
    # itself from drifting with measurement jitter.
    rng = np.random.default_rng(1)
    queries = [jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 256)))
               for _ in range(80)]
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="none",
                        estimate_beta=0.0)
    eng.executor.warmup(1, 256)
    probe = eng.serve(queries[:6], lambda q: [1.0] * 4)
    service = float(probe.service_latencies[2:].mean())
    slo = 10.0 * service
    wl = dict(burst_rate=32.0 / service, base_rate=0.0,
              mean_burst=500 * service, mean_gap=10 * service, seed=0)
    none_m = shed_m = None
    for _ in range(3):
        none_m = eng.serve(queries, lambda q: [1.0] * 4,
                           workload="bursty", workload_kwargs=wl)
        shed_m = eng.serve(queries, lambda q: [1.0] * 4,
                           workload="bursty", workload_kwargs=wl,
                           admission="slo_shed",
                           admission_kwargs=dict(slo=slo, margin=5.0))
        if (none_m.tail_latency(99) > slo and shed_m.num_shed > 0
                and shed_m.num_admitted > 0
                and shed_m.tail_latency(99) <= slo):
            break
    assert none_m.tail_latency(99) > slo
    assert none_m.num_shed == 0
    assert shed_m.num_shed > 0
    assert shed_m.tail_latency(99) <= slo
    s = shed_m.summary()
    assert s["shed_rate"] > 0
    assert np.isfinite(s["goodput_qps"])
    assert s["slo_latency_s"] == slo
    # identical metric surface with and without the control plane
    assert set(s.keys()) == set(none_m.summary().keys())


def test_engine_open_loop_bursty_reports_queueing(setup):
    """Open-loop serving through the same engine: queueing delay is
    accounted separately from measured service latency."""
    cfg, params, queries = setup
    eng = ServingEngine(cfg, params, num_eps=4, scheduler="none")
    eng.executor.warmup(1, 64)
    # calibrate the burst to this host: measure one closed-loop query
    probe = eng.serve(queries[:2], lambda q: [1.0] * 4)
    service = float(probe.service_latencies.mean())
    m = eng.serve(queries[:20], lambda q: [1.0] * 4, workload="bursty",
                  workload_kwargs=dict(burst_rate=4.0 / service,
                                       base_rate=0.0,
                                       mean_burst=40 * service,
                                       mean_gap=5 * service, seed=0))
    assert m.workload == "bursty"
    assert np.allclose(m.latencies, m.queue_delays + m.service_latencies)
    assert m.queue_delays.max() > 0           # the burst outran the pipe
    assert np.all(m.service_latencies > 0)
    assert m.offered_load > 0 and np.isfinite(m.achieved_load)
