"""repro.control: admission registries, closed-loop bit-identity, SLO
shedding under overload (sim + live + fleet), adaptive batch bounds,
and load-profile autoscaling."""
import dataclasses

import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.cluster import simulate_cluster
from repro.control import (
    AdmissionPolicy,
    AdmissionView,
    AdaptiveBatchAdmission,
    available_admission_policies,
    available_autoscalers,
    make_admission,
    make_autoscaler,
    register_admission,
    resolve_admission,
    resolve_autoscaler,
    unregister_admission,
)
from repro.core import generate_events, simulate, synthetic_database

BUILTIN_ADMISSION = ("adaptive_batch", "none", "queue_cap", "slo_shed")
BUILTIN_AUTOSCALERS = ("load_profile", "static")


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


@pytest.fixture(scope="module")
def cap(db):
    """Interference-free peak throughput (queries / time unit)."""
    return simulate(db, 4, scheduler="none", events=[], num_queries=10).peak_throughput


@pytest.fixture(scope="module")
def service(db):
    """Steady pipelined service latency of one query."""
    t = simulate(db, 4, scheduler="none", events=[], num_queries=10)
    return float(t.service_latencies[-1])


def overload_kwargs(cap, seed=3):
    """A bursty workload whose bursts far exceed pipeline capacity."""
    return dict(
        workload="bursty",
        workload_kwargs=dict(
            burst_rate=3.0 * cap,
            base_rate=0.5 * cap,
            mean_burst=2000.0 / cap,
            mean_gap=1000.0 / cap,
            seed=seed,
        ),
    )


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_policies():
    names = available_admission_policies()
    for name in BUILTIN_ADMISSION:
        assert name in names
    scalers = available_autoscalers()
    for name in BUILTIN_AUTOSCALERS:
        assert name in scalers


def test_registry_kwargs_filtered_per_policy():
    """One kwargs superset constructs any policy (cap means nothing to
    slo_shed, slo nothing to queue_cap)."""
    for name in BUILTIN_ADMISSION:
        p = make_admission(name, cap=4, slo=1.0, margin=2.0)
        assert isinstance(p, AdmissionPolicy)
    assert make_admission("queue_cap", cap=4, slo=1.0).cap == 4
    assert make_admission("slo_shed", cap=4, slo=1.0).slo == 1.0


def test_registry_unknown_and_validation():
    with pytest.raises(ValueError, match="unknown admission"):
        make_admission("does-not-exist")
    with pytest.raises(TypeError):
        make_admission("slo_shed")  # slo is required
    with pytest.raises(ValueError):
        make_admission("slo_shed", slo=0.0)
    with pytest.raises(ValueError):
        make_admission("queue_cap", cap=0)
    with pytest.raises(ValueError, match="unknown autoscaler"):
        make_autoscaler("does-not-exist")
    with pytest.raises(ValueError):
        make_autoscaler("load_profile", target_util=0.0)


def test_resolve_admission_none_and_instances():
    assert resolve_admission(None) is None
    with pytest.raises(ValueError, match="no admission policy"):
        resolve_admission(None, {"slo": 1.0})
    inst = make_admission("queue_cap", cap=7)
    assert resolve_admission(inst) is inst
    with pytest.raises(ValueError, match="already-constructed"):
        resolve_admission(inst, {"cap": 3})
    scaler = make_autoscaler("static")
    assert resolve_autoscaler(scaler) is scaler
    assert resolve_autoscaler(None).name == "static"


def test_register_custom_policy():
    @register_admission("_test_flaky_gate")
    class FlakyGate:
        admits_all = False

        def admit(self, view):
            return view.query % 2 == 0

        def reset(self):
            pass

    try:
        p = make_admission("_test_flaky_gate")
        assert p.name == "_test_flaky_gate"
        view = AdmissionView(query=1, arrival=0.0, wait=0.0, est_service=1.0)
        assert not p.admit(view)
    finally:
        unregister_admission("_test_flaky_gate")
    with pytest.raises(ValueError):
        make_admission("_test_flaky_gate")


def test_admission_view_queue_length():
    v = AdmissionView(query=0, arrival=1.0, wait=10.0, est_service=2.0)
    assert v.queue_length == 5.0
    unknown = AdmissionView(query=0, arrival=1.0, wait=10.0, est_service=float("nan"))
    assert unknown.queue_length == 0.0


# ---------------------------------------------------------------------------
# closed loop: the control plane must be invisible
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "admission,admission_kwargs",
    [
        ("none", {}),
        ("queue_cap", {"cap": 4}),
        ("slo_shed", {"slo": 1e9}),
        ("adaptive_batch", {"slo": 1e9}),
    ],
)
@pytest.mark.parametrize("scheduler", ["odin", "none"])
def test_closed_loop_bit_identical_to_no_policy(
    db, scheduler, admission, admission_kwargs
):
    """Closed loops have zero predicted wait, so no built-in policy may
    shed — and the trace must be bit-identical to running without a
    control plane at all."""
    base = simulate(db, 4, scheduler=scheduler, num_queries=400, seed=0)
    ctl = simulate(
        db,
        4,
        scheduler=scheduler,
        num_queries=400,
        seed=0,
        admission=admission,
        admission_kwargs=admission_kwargs,
    )
    assert ctl.num_shed == 0
    assert np.array_equal(base.latencies, ctl.latencies)
    assert np.array_equal(base.throughputs, ctl.throughputs)
    assert np.array_equal(base.queue_delays, ctl.queue_delays)
    assert base.configs_trace == ctl.configs_trace
    assert base.num_rebalances == ctl.num_rebalances


@settings(max_examples=15, deadline=None)
@given(
    cap_=st.integers(min_value=1, max_value=64),
    slo_services=st.floats(min_value=1.5, max_value=100.0),
    seed=st.integers(min_value=0, max_value=7),
)
def test_property_no_shed_below_capacity_closed_loop(cap_, slo_services, seed):
    """queue_cap / slo_shed never shed a closed-loop query, for any cap
    >= 1 and any feasible SLO (>= one service latency)."""
    db = synthetic_database("vgg16", seed=0)
    probe = simulate(db, 4, scheduler="none", events=[], num_queries=5)
    slo = slo_services * float(probe.service_latencies[-1])
    base = simulate(db, 4, scheduler="odin", num_queries=120, seed=seed)
    for admission, kwargs in (
        ("queue_cap", {"cap": cap_}),
        ("slo_shed", {"slo": slo}),
    ):
        t = simulate(
            db,
            4,
            scheduler="odin",
            num_queries=120,
            seed=seed,
            admission=admission,
            admission_kwargs=kwargs,
        )
        assert t.num_shed == 0
        assert np.array_equal(t.latencies, base.latencies)


# ---------------------------------------------------------------------------
# overload: slo_shed holds the tail where none cannot
# ---------------------------------------------------------------------------


def test_slo_shed_holds_p99_of_admitted_under_overload(db, cap, service):
    """The acceptance scenario in simulate(): bursty offered load above
    capacity — none blows through the SLO, slo_shed keeps every
    admitted query inside it."""
    slo = 3.0 * service
    kw = dict(scheduler="none", events=[], num_queries=4000, **overload_kwargs(cap))
    none_t = simulate(db, 4, **kw)
    shed_t = simulate(db, 4, admission="slo_shed", admission_kwargs={"slo": slo}, **kw)
    assert none_t.tail_latency(99) > slo
    assert none_t.num_shed == 0
    assert shed_t.num_shed > 0
    assert shed_t.tail_latency(99) <= slo
    assert shed_t.slo_attainment == 1.0
    # offered load counts shed arrivals; goodput only admitted-in-SLO
    assert shed_t.num_offered == 4000
    assert shed_t.num_admitted + shed_t.num_shed == 4000
    assert shed_t.offered_load == pytest.approx(none_t.offered_load)
    assert shed_t.goodput_qps <= shed_t.achieved_load


def test_slo_shed_chunked_matches_scalar_under_overload(db, cap, service):
    """The chunk admission pre-pass (predicted ledger) must make the
    same decisions as the scalar tick in the simulator, where the
    estimated beat is exact."""
    slo = 3.0 * service
    kw = dict(
        scheduler="none",
        events=[],
        num_queries=3000,
        admission="slo_shed",
        admission_kwargs={"slo": slo},
        **overload_kwargs(cap),
    )
    chunked = simulate(db, 4, chunking=True, **kw)
    scalar = simulate(db, 4, chunking=False, **kw)
    assert chunked.num_shed == scalar.num_shed
    assert np.array_equal(chunked.shed_arrivals, scalar.shed_arrivals)
    # open-loop ledger values agree up to float re-association, the
    # same tolerance the chunked fast path itself is held to
    # (tests/test_batching.py)
    assert np.allclose(chunked.latencies, scalar.latencies, rtol=1e-9)


def test_queue_cap_bounds_depth_under_overload(db, cap):
    uncapped = simulate(
        db, 4, scheduler="none", events=[], num_queries=3000, **overload_kwargs(cap)
    )
    capped = simulate(
        db,
        4,
        scheduler="none",
        events=[],
        num_queries=3000,
        admission="queue_cap",
        admission_kwargs={"cap": 8},
        **overload_kwargs(cap),
    )
    assert capped.num_shed > 0
    assert capped.queue_depths.max() < uncapped.queue_depths.max()
    # the cap bounds the *queued* backlog; in-flight queries ride on top
    assert capped.queue_depths.max() <= 8 + 8


def test_shed_summary_keys_identical_across_policies(db, cap):
    """One metric surface: a shed run and a plain run expose the same
    summary keys (values differ, shape never)."""
    kw = dict(scheduler="none", events=[], num_queries=500, **overload_kwargs(cap))
    plain = simulate(db, 4, **kw).summary()
    shed = simulate(
        db, 4, admission="queue_cap", admission_kwargs={"cap": 4}, **kw
    ).summary()
    assert set(plain.keys()) == set(shed.keys())
    assert plain["num_shed"] == 0 and shed["num_shed"] > 0


# ---------------------------------------------------------------------------
# adaptive_batch: SLO-aware max_batch control
# ---------------------------------------------------------------------------


class _RecordingAdaptive(AdaptiveBatchAdmission):
    """Records every bound the run loop consults."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.bounds = []

    def max_chunk_bound(self):
        b = super().max_chunk_bound()
        self.bounds.append(b)
        return b


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_adaptive_batch_stays_within_declared_bounds(db, cap, service, seed):
    policy = _RecordingAdaptive(
        slo=3.0 * service, min_batch=2, max_batch=16, window=32, interval=8
    )
    simulate(
        db,
        4,
        scheduler="none",
        events=[],
        num_queries=2000,
        admission=policy,
        workload="bursty",
        workload_kwargs=dict(
            burst_rate=3.0 * cap,
            base_rate=0.5 * cap,
            mean_burst=500.0 / cap,
            mean_gap=500.0 / cap,
            seed=seed,
        ),
    )
    assert policy.bounds, "the run loop never consulted the bound"
    assert min(policy.bounds) >= 2
    assert max(policy.bounds) <= 16
    # overload pushes p99 queue delay past the SLO: the bound must move
    assert min(policy.bounds) < 16


def test_adaptive_batch_grows_back_when_quiet(db, cap, service):
    policy = AdaptiveBatchAdmission(
        slo=3.0 * service, min_batch=1, max_batch=8, window=16, interval=4
    )
    # closed loop: zero queue delay, the bound climbs to max and stays
    simulate(db, 4, scheduler="none", events=[], num_queries=200, admission=policy)
    assert policy.max_chunk_bound() == 8


# ---------------------------------------------------------------------------
# fleet: admission + autoscaling through the cluster
# ---------------------------------------------------------------------------


def fleet_overload(cap, num_replicas, seed=6):
    return dict(
        workload="bursty",
        workload_kwargs=dict(
            burst_rate=2.0 * num_replicas * cap,
            base_rate=0.375 * num_replicas * cap,
            mean_burst=80.0 / cap,
            mean_gap=250.0 / cap,
            seed=seed,
        ),
    )


def test_cluster_admission_none_and_static_bit_identical(db, cap):
    """admission="none" + autoscaler="static" must reproduce the
    pre-control-plane fleet bit for bit."""
    kw = dict(
        scheduler="odin",
        alpha=4,
        num_queries=600,
        router="least_outstanding",
        **fleet_overload(cap, 4),
    )
    base = simulate_cluster(db, 4, 4, **kw)
    ctl = simulate_cluster(db, 4, 4, admission="none", autoscaler="static", **kw)
    assert np.array_equal(base.assignments, ctl.assignments)
    assert np.array_equal(base.fleet.latencies, ctl.fleet.latencies)
    assert ctl.num_shed == 0
    assert ctl.summary()["mean_active_replicas"] == 4.0


def test_cluster_slo_shed_holds_fleet_tail(db, cap, service):
    """Fleet acceptance: slo_shed p99-of-admitted meets the SLO where
    none violates it, with replica-scoped interference in play."""
    slo = 3.0 * service
    events = [
        dataclasses.replace(ev, replica=2)
        for ev in generate_events(300, 4, db.num_scenarios, 2, 100, 5)
    ]
    kw = dict(
        scheduler="odin",
        alpha=10,
        num_queries=2000,
        events=events,
        router="odin_aware",
        **fleet_overload(cap, 4),
    )
    none_ct = simulate_cluster(db, 4, 4, **kw)
    shed_ct = simulate_cluster(
        db, 4, 4, admission="slo_shed", admission_kwargs={"slo": slo}, **kw
    )
    assert none_ct.fleet.tail_latency(99) > slo
    assert shed_ct.num_shed > 0
    # interference can begin between decision and execution: allow a
    # whisker past the SLO, and require the bulk strictly inside it
    assert shed_ct.fleet.tail_latency(99) <= 1.05 * slo
    assert shed_ct.fleet.slo_attainment >= 0.98
    assert shed_ct.num_admitted + shed_ct.num_shed == 2000
    assert len(shed_ct.shed_arrivals) == shed_ct.num_shed


def test_load_profile_autoscaler_tracks_diurnal_load(db, cap):
    """Day/night swings activate and drain replicas; quiet phases run
    on a subset, peaks re-activate the fleet."""
    ct = simulate_cluster(
        db,
        4,
        4,
        scheduler="none",
        num_queries=4000,
        router="least_outstanding",
        workload="diurnal",
        workload_kwargs=dict(
            mean_rate=1.5 * cap,
            period=4000.0 / cap,
            amplitude=0.8,
            seed=5,
        ),
        autoscaler="load_profile",
    )
    counts = ct.active_counts
    assert len(ct.active_timeline) >= 2, "active set never changed"
    assert counts.min() < 4, "never drained"
    assert counts.max() == 4, "never used the whole fleet"
    s = ct.summary()
    assert 1.0 <= s["mean_active_replicas"] < 4.0
    assert s["autoscaler"] == "load_profile"


def test_static_autoscaler_prefix(db, cap):
    """static(n_active=k) keeps the router on the first k replicas."""
    ct = simulate_cluster(
        db,
        4,
        4,
        scheduler="none",
        num_queries=400,
        router="round_robin",
        workload="poisson",
        workload_kwargs=dict(rate=2.0 * cap, seed=1),
        autoscaler="static",
        autoscaler_kwargs={"n_active": 2},
    )
    counts = ct.replica_counts
    assert counts[0] + counts[1] == 400
    assert counts[2] == counts[3] == 0


def test_closed_loop_cluster_with_load_profile_degenerates_to_static(db):
    """No arrival clock -> the measured offered rate is the fleet's own
    service rate -> the autoscaler keeps everyone active."""
    base = simulate_cluster(db, 4, 2, scheduler="none", num_queries=200)
    ct = simulate_cluster(
        db, 4, 2, scheduler="none", num_queries=200, autoscaler="load_profile"
    )
    assert ct.summary()["mean_active_replicas"] == 2.0
    assert np.array_equal(base.fleet.latencies, ct.fleet.latencies)
