"""Per-architecture smoke tests: REDUCED configs of the same family.

For each of the 10 assigned architectures: instantiate the reduced
variant (<=2 blocks / <=512 d_model / <=4 experts), run one forward and
one train step on CPU, assert output shapes and absence of NaNs; for
decoders additionally check prefill+decode agreement with the full
forward pass (cache correctness).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model
from repro.models.layers import embed
from repro.training import AdamWConfig, adamw_update, init_adamw

B, S = 2, 64


def _batch(cfg, rng):
    labels = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.embedding_inputs:
        return {"embeds": jax.random.normal(rng, (B, S, cfg.d_model)) * 0.02,
                "labels": labels}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
            "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    assert cfg.source, "config must cite its source"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_blocks <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng, jnp.float32)
    batch = _batch(cfg, rng)

    logits, _ = jax.jit(lambda p, b: model.forward(
        p, b.get("tokens"), b.get("embeds")))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))

    # one optimizer step
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_adamw(params)

    @jax.jit
    def step(p, s, b):
        (loss, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p, s, m = adamw_update(opt, p, g, s)
        return p, s, loss

    params2, _, loss = step(params, state, batch)
    assert np.isfinite(float(loss))
    changed = sum(
        int(not np.array_equal(np.asarray(a), np.asarray(b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_smoke_config(a).is_decoder])
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:  # avoid capacity-drop mismatches in the check
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe,
            capacity_factor=float(cfg.moe.num_experts)
            / cfg.moe.num_experts_per_tok))
    model = Model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init_params(rng, jnp.float32)
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)

    if cfg.embedding_inputs:
        full_logits, _ = model.forward(
            params, embeds=embed(params["embed"], toks))
        pre = dict(embeds=embed(params["embed"], toks[:, :S]))
    else:
        full_logits, _ = model.forward(params, tokens=toks)
        pre = dict(tokens=toks[:, :S])

    cache = model.init_cache(B, S + 8, jnp.float32)
    lp, cache = model.prefill(params, cache=cache, **pre)
    np.testing.assert_allclose(np.asarray(lp[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               atol=2e-3, rtol=1e-3)
    lg, _ = model.decode_step(params, toks[:, S:S + 1], cache,
                              jnp.array(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, S]),
                               atol=2e-3, rtol=1e-3)


def test_encoder_has_no_decode():
    cfg = get_smoke_config("hubert-xlarge")
    assert not cfg.is_decoder
    assert not cfg.causal


def test_moe_dropless_at_decode():
    """Decode groups have one token: routing never drops (serving fidelity)."""
    from repro.models.moe import capacity_per_group
    cfg = get_smoke_config("deepseek-moe-16b")
    assert capacity_per_group(1, cfg.moe) >= cfg.moe.num_experts_per_tok
