"""Scheduler-policy API: registry, shared runtime, policy parity."""
import numpy as np
import pytest

from repro.core import (
    InterferenceEvent,
    LayerDatabase,
    balanced_config,
    lls_rebalance,
    optimal_partition,
    simulate,
    synthetic_database,
    throughput,
)
from repro.core.odin import OdinExplorer
from repro.schedulers import (
    HybridExplorer,
    InterferenceDetector,
    OdinPolicy,
    RebalanceRuntime,
    SchedulerPolicy,
    available_schedulers,
    make_scheduler,
    register_scheduler,
    unregister_scheduler,
)

BUILTINS = ("odin", "lls", "oracle", "none", "hybrid")


@pytest.fixture(scope="module")
def db():
    return synthetic_database("vgg16", seed=0)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_builtins():
    names = available_schedulers()
    for name in BUILTINS:
        assert name in names


def test_registry_round_trip_every_builtin():
    """One superset of kwargs constructs every registered policy."""
    for name in available_schedulers():
        pol = make_scheduler(name, alpha=3, rel_threshold=0.1,
                             solver=lambda cfg, src: list(cfg))
        assert isinstance(pol, SchedulerPolicy)
        for meth in ("detect", "make_explorer", "finish", "reset"):
            assert callable(getattr(pol, meth))
        pol.reset()
        assert getattr(pol, "name", name)


def test_registry_kwargs_are_filtered_per_policy():
    pol = make_scheduler("odin", alpha=7, rel_threshold=0.05,
                         solver="ignored-by-odin")
    assert pol.alpha == 7
    assert pol.detector.rel_threshold == 0.05


def test_registry_unknown_name():
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("does-not-exist")


def test_register_custom_scheduler():
    @register_scheduler("_test_custom", alpha=5)
    class CustomPolicy(OdinPolicy):
        pass

    try:
        pol = make_scheduler("_test_custom")
        assert isinstance(pol, CustomPolicy)
        assert pol.alpha == 5          # registration default applied
        assert pol.name == "_test_custom"
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("_test_custom")(CustomPolicy)
    finally:
        unregister_scheduler("_test_custom")
    with pytest.raises(ValueError):
        make_scheduler("_test_custom")


def test_simulate_accepts_policy_instance(db):
    kw = dict(num_queries=300, freq_period=50, duration=50, seed=3)
    r_name = simulate(db, 4, scheduler="odin", alpha=4, **kw)
    r_inst = simulate(db, 4, scheduler=OdinPolicy(alpha=4), **kw)
    assert r_inst.configs_trace == r_name.configs_trace
    assert r_inst.scheduler == "odin"  # registry-stamped policy name


# ---------------------------------------------------------------------------
# simulator <-> MeasuredTimeSource runtime parity
# ---------------------------------------------------------------------------

# Power-of-two slowdown factors make database stage times and
# MeasuredTimeSource stage times bit-identical (pure exponent shifts),
# so the two drivers must walk the exact same trial/config sequence.
_FACTORS = {0: 1.0, 1: 2.0, 2: 4.0}


def _uniform_db(base):
    table = np.stack([base * _FACTORS[k] for k in range(3)], axis=1)
    return LayerDatabase(table, ["none", "x2", "x4"])


@pytest.mark.parametrize("sched", ["odin", "lls", "hybrid"])
def test_runtime_parity_with_simulator(sched):
    rng = np.random.default_rng(11)
    base = rng.integers(1, 9, size=16).astype(float)
    db = _uniform_db(base)
    events = [InterferenceEvent(start=25, duration=40, ep=1, scenario=2),
              InterferenceEvent(start=90, duration=35, ep=3, scenario=1),
              InterferenceEvent(start=150, duration=30, ep=0, scenario=2)]
    cfg0 = balanced_config(db.num_layers, 4)
    n = 220

    r = simulate(db, 4, scheduler=sched, alpha=3, num_queries=n,
                 events=events, initial_config=cfg0, rel_threshold=0.02)

    from repro.pipeline.executor import MeasuredTimeSource
    rt = RebalanceRuntime(
        make_scheduler(sched, alpha=3, rel_threshold=0.02), cfg0)
    for q in range(n):
        slow = [1.0] * 4
        for ev in events:
            if ev.start <= q < ev.end:
                slow[ev.ep] = _FACTORS[ev.scenario]
        step = rt.poll(MeasuredTimeSource(base, slow))
        assert step.config == r.configs_trace[q], f"config diverged at q={q}"
        assert step.serial == bool(r.serial_mask[q]), f"serial mask at q={q}"
    assert rt.num_rebalances == r.num_rebalances
    assert rt.total_trials == r.total_trials
    assert rt.mitigation_lengths == r.mitigation_lengths


# ---------------------------------------------------------------------------
# oracle as a normal policy
# ---------------------------------------------------------------------------


def test_oracle_policy_matches_special_case_output(db):
    """Identical to the old `if scheduler == "oracle"` sim branch."""
    n, num_eps = 400, 4
    events = [InterferenceEvent(start=50, duration=80, ep=2, scenario=9),
              InterferenceEvent(start=200, duration=60, ep=0, scenario=4)]
    r = simulate(db, num_eps, scheduler="oracle", num_queries=n,
                 events=events)
    # oracle costs nothing: no serial queries, no trials, no rebalances
    assert r.serial_mask.sum() == 0
    assert r.total_trials == 0
    assert r.num_rebalances == 0
    assert r.mitigation_lengths == []
    # and every query runs the per-scenario DP optimum
    for q in range(n):
        scen = [0] * num_eps
        for ev in events:
            if ev.start <= q < ev.end:
                scen[ev.ep] = ev.scenario
        opt_cfg, opt_T = optimal_partition(db, scen, num_eps)
        assert r.configs_trace[q] == list(opt_cfg)
        assert r.throughputs[q] == pytest.approx(opt_T)


def test_oracle_requires_solver():
    with pytest.raises(TypeError):
        make_scheduler("oracle")


# ---------------------------------------------------------------------------
# shared detector
# ---------------------------------------------------------------------------


class _ConstSource:
    def __init__(self, times):
        self.times = np.asarray(times, float)

    def stage_times(self, config):
        return self.times


def test_detector_rel_mode_matches_paper_rule():
    det = InterferenceDetector(rel_threshold=0.1, mode="rel")
    cfg = [1, 1]
    assert not det.observe(cfg, _ConstSource([1.0, 2.0]))  # records ref
    assert not det.observe(cfg, _ConstSource([1.0, 2.1]))  # within 10%
    assert det.observe(cfg, _ConstSource([1.0, 2.5]))      # beyond 10%
    det.rearm(cfg, _ConstSource([1.0, 2.5]))
    assert not det.observe(cfg, _ConstSource([1.0, 2.5]))
    assert det.observe(cfg, _ConstSource([1.0, 2.0]))      # departure too


def test_detector_ema_hysteresis_debounces_spikes():
    det = InterferenceDetector(rel_threshold=0.1, mode="ema",
                               ema_beta=0.2, hysteresis=2)
    cfg = [1, 1]
    assert not det.observe(cfg, _ConstSource([1.0, 2.0]))  # records ref
    # a single-query spike must NOT trigger (streak resets)
    assert not det.observe(cfg, _ConstSource([1.0, 4.0]))
    assert not det.observe(cfg, _ConstSource([1.0, 2.0]))
    assert not det.observe(cfg, _ConstSource([1.0, 4.0]))
    # ...but a sustained shift must
    assert det.observe(cfg, _ConstSource([1.0, 4.0]))


def test_detector_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown detector mode"):
        InterferenceDetector(mode="magic")


def test_policies_accept_detector_mode_string(db):
    pol = make_scheduler("odin", alpha=4, rel_threshold=0.02,
                         detector="ema")
    assert pol.detector.mode == "ema"
    r = simulate(db, 4, scheduler=pol, num_queries=400,
                 freq_period=50, duration=50, seed=3)
    assert r.num_rebalances >= 1


# ---------------------------------------------------------------------------
# OdinExplorer failed-move fix
# ---------------------------------------------------------------------------


class _LinearSource:
    """stage_times = per-stage weight x layer count."""

    def __init__(self, weights):
        self.weights = np.asarray(weights, float)

    def stage_times(self, config):
        return self.weights * np.asarray(config, float)


def test_failed_move_records_no_duplicate_trial():
    """A 1-layer affected stage cannot donate: the step must not log the
    unchanged configuration as a fresh trial measurement."""
    ex = OdinExplorer([1, 15], alpha=2)
    src = _LinearSource([10.0, 0.1])       # stage 0 (1 layer) is slowest
    steps = 0
    while not ex.done:
        cfg = ex.step(src)
        assert cfg == [1, 15]              # move impossible, config fixed
        steps += 1
    assert steps == 2                      # patience alpha=2 still bounds
    res = ex.result()
    assert res.trials == []                # ...but no phantom trials
    assert res.config == [1, 15]


def test_move_reports_failure():
    ex = OdinExplorer([1, 3], alpha=2)
    assert not ex._move(0, 1)
    assert ex.C == [1, 3]
    assert ex._move(1, 0)
    assert ex.C == [2, 2]


# ---------------------------------------------------------------------------
# hybrid policy
# ---------------------------------------------------------------------------


def test_hybrid_escalates_to_odin_on_plateau(db):
    """When LLS plateaus, hybrid must recover at least LLS's throughput
    and run ODIN exploration from the best LLS config."""
    cfg0, _ = optimal_partition(db, [0] * 4, 4)
    found = 0
    for ep in range(4):
        for scen in range(1, 13):
            s = [0] * 4
            s[ep] = scen
            from repro.core import SimTimeSource
            src = SimTimeSource(db, s)
            lls_res = lls_rebalance(cfg0, src)
            hy = HybridExplorer(cfg0, alpha=10)
            while not hy.done:
                hy.step(src)
            res = hy.result()
            assert res.throughput >= lls_res.throughput - 1e-12
            # best-seen: never worse than any configuration LLS measured
            if lls_res.trials:
                assert res.throughput >= max(
                    t.throughput for t in lls_res.trials) - 1e-12
            if hy._odin is not None:
                found += 1
                assert res.num_trials >= lls_res.num_trials
    assert found > 0, "no scenario exercised the ODIN escalation path"


def test_hybrid_in_simulator(db):
    kw = dict(num_queries=800, freq_period=100, duration=100, seed=3)
    r_h = simulate(db, 4, scheduler="hybrid", alpha=10, **kw)
    r_n = simulate(db, 4, scheduler="none", **kw)
    assert r_h.num_rebalances > 0
    assert r_h.throughputs.mean() > r_n.throughputs.mean()
    for c in r_h.configs_trace:
        assert sum(c) == db.num_layers


# ---------------------------------------------------------------------------
# runtime edge behaviour
# ---------------------------------------------------------------------------


def test_runtime_reset_abandons_phase(db):
    from repro.core import SimTimeSource
    cfg0 = balanced_config(db.num_layers, 4)
    rt = RebalanceRuntime(make_scheduler("odin", alpha=10,
                                         rel_threshold=0.02), cfg0)
    clean = SimTimeSource(db, [0, 0, 0, 0])
    hit = SimTimeSource(db, [12, 0, 0, 0])
    rt.poll(clean)                        # baseline
    step = rt.poll(hit)
    assert step.serial and rt.exploring
    rt.reset(cfg0)
    assert not rt.exploring
    assert rt.config == cfg0
    # detector re-armed: next observation records a fresh baseline
    assert not rt.poll(hit).serial


def test_runtime_accounting_charges_serial_queries(db):
    """total_trials / mitigation_lengths count serial queries consumed,
    and every counted phase is reflected in the serial mask."""
    for sched in ("odin", "lls", "hybrid"):
        r = simulate(db, 4, scheduler=sched, alpha=4, num_queries=900,
                     freq_period=150, duration=100, seed=2)
        assert r.total_trials == sum(r.mitigation_lengths)
        assert len(r.mitigation_lengths) == r.num_rebalances or \
            r.num_rebalances - len(r.mitigation_lengths) == 1  # in-flight
        # serial queries = committed phase steps + any in-flight steps
        assert int(r.serial_mask.sum()) >= r.total_trials


def test_policy_instance_reset_between_runs(db):
    """A reused policy instance starts each run with a fresh baseline."""
    pol = OdinPolicy(alpha=4)
    kw = dict(num_queries=300, freq_period=50, duration=50, seed=3)
    first = simulate(db, 4, scheduler=pol, **kw)
    again = simulate(db, 4, scheduler=pol, **kw)
    assert again.configs_trace == first.configs_trace
    assert again.num_rebalances == first.num_rebalances


def test_static_policy_never_rebalances(db):
    r = simulate(db, 4, scheduler="none", num_queries=300,
                 freq_period=20, duration=20, seed=1)
    assert r.num_rebalances == 0
    assert all(c == r.configs_trace[0] for c in r.configs_trace)
    assert throughput(np.asarray([1.0])) == 1.0  # smoke: helper import
