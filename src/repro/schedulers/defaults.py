"""Shared scheduler tuning defaults — documented once, used everywhere.

Before these constants existed, ``simulate()`` defaulted
``rel_threshold=0.02`` while ``ServingEngine`` defaulted ``0.15``: the
same policy name meant a different detector depending on the driver.
Both drivers now resolve ``rel_threshold=None`` to
:data:`DEFAULT_REL_THRESHOLD`, so sim and engine agree.

* :data:`DEFAULT_REL_THRESHOLD` — the paper's §3.1 monitoring rule
  triggers when the bottleneck stage time shifts by more than this
  fraction relative to the post-rebalance reference.  2% is tight
  enough to catch every Table-1 scenario (the mildest is ~5-7%
  slowdown) without firing on database-level noise.
* :data:`DEFAULT_ALPHA` — ODIN's exploration patience (paper evaluates
  α=2 and α=10; 10 is the headline setting).
* :data:`MEASURED_DETECTOR_MODE` — wall-clock stage times jitter well
  beyond 2% query-to-query, so the live engine keeps the shared
  threshold but runs the detector in its EMA/hysteresis mode
  (``InterferenceDetector(mode="ema")``): the reference is a smoothed
  average and a trigger needs ``hysteresis`` consecutive out-of-band
  observations.  Same rule, debounced — not a different threshold.
"""
from __future__ import annotations

from typing import Optional

DEFAULT_REL_THRESHOLD: float = 0.02
DEFAULT_ALPHA: int = 10
MEASURED_DETECTOR_MODE: str = "ema"


def resolve_rel_threshold(value: Optional[float]) -> float:
    """``None`` -> the shared default; explicit values pass through."""
    return DEFAULT_REL_THRESHOLD if value is None else float(value)
