"""The one rebalance state machine shared by simulator and live engine.

Both ``repro.core.simulator.simulate`` and
``repro.serving.ServingEngine.serve`` previously hand-rolled the same
loop (detect → drive the explorer one trial per serially-processed query
→ commit) with drifting details; :class:`RebalanceRuntime` owns it once.

Per query the driver calls :meth:`poll` with the current
:class:`~repro.core.pipeline_state.StageTimeSource` and receives the
configuration the query must run with plus whether it is a serial
(exploration-trial) query:

* no phase active, ``policy.detect`` quiet → steady pipelined query;
* ``detect`` fires → a phase starts.  Serial explorers (ODIN, LLS,
  hybrid) consume one query per ``step()``; *instant* explorers
  (``serial = False``, e.g. the DP oracle) run to completion inside the
  same poll and the query proceeds pipelined on the new configuration —
  which is exactly the old ``if scheduler == "oracle"`` special case,
  now expressed as a normal policy;
* the explorer finishing commits its result: the runtime adopts the
  configuration, updates trial accounting, and calls ``policy.finish``
  so detection re-arms against the post-rebalance bottleneck.

Accounting matches the paper's: ``num_rebalances`` counts phases that
cost at least one serial query (the oracle is free), ``total_trials`` /
``mitigation_lengths`` mirror Fig. 8's exploration overhead.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional, Sequence

if TYPE_CHECKING:  # annotation-only: keeps repro.core <-> schedulers acyclic
    from repro.core.pipeline_state import StageTimeSource
    from repro.schedulers.base import SchedulerPolicy


@dataclasses.dataclass
class RuntimeStep:
    """What one polled query should do."""
    config: List[int]          # configuration to process the query with
    serial: bool               # True = exploration trial (serial query)
    committed: bool = False    # a rebalancing phase committed at this step
    #: Mesh assignment (devices per stage) the query runs with;
    #: ``None`` on unsharded runs (docs/SHARDING.md).
    mesh: Optional[List[int]] = None


class RebalanceRuntime:
    """Detect → explore → commit driver around one SchedulerPolicy."""

    #: Safety bound on instant (serial=False) explorers, which complete
    #: inside a single poll: a plugin explorer that never sets ``done``
    #: raises instead of hanging the serving loop.
    MAX_INSTANT_STEPS = 10_000

    def __init__(self, policy: SchedulerPolicy, config: Sequence[int],
                 mesh: Optional[Sequence[int]] = None):
        self.policy = policy
        self.policy.reset()       # a runtime is a fresh serving window
        self.config = list(config)
        #: Committed mesh assignment (devices per stage); ``None`` on
        #: unsharded runs — every mesh branch below is then dead and
        #: the runtime is bit-identical to the pre-mesh build.
        self.mesh = list(mesh) if mesh is not None else None
        self.num_mesh_resizes = 0
        self.explorer = None
        self.num_rebalances = 0
        self.total_trials = 0
        self.mitigation_lengths: List[int] = []
        self._phase_steps = 0     # serial queries consumed by this phase
        #: Most recent StageTimeSource this runtime was polled/armed
        #: with; what read-only observers (the cluster's routers) probe
        #: for the replica's current estimated stage times.
        self.last_source: Optional[StageTimeSource] = None

    @property
    def exploring(self) -> bool:
        """True while a rebalancing phase is in progress."""
        return self.explorer is not None

    def steady_poll_stable(self) -> bool:
        """True when one steady ``poll`` answers for a whole chunk.

        The run loop's vectorized fast path polls once per
        environment-steady segment instead of once per query.  That is
        equivalent exactly when the policy advertises
        ``steady_detect_stable``: ``detect`` is side-effect-free and
        returns the same answer while (config, stage times) are
        unchanged, including immediately after ``finish`` re-arms it.
        True for the built-in policies on the paper's pure relative
        threshold; False for the EMA/hysteresis detector mode (every
        observation moves the reference) and for unknown plugins.
        """
        return bool(getattr(self.policy, "steady_detect_stable", False))

    def steady_step(self) -> RuntimeStep:
        """A pipelined step on the committed config, without polling.

        For drivers that cannot consult the policy on some query (the
        live engine has no stage-time estimates before the first
        measurement) but still need a :class:`RuntimeStep` to execute.
        """
        return RuntimeStep(list(self.config), serial=False,
                           mesh=self._mesh_copy())

    # -- read-only state exposure (cluster routing; docs/CLUSTER.md) ---------
    def interference_score(self) -> float:
        """Positive relative bottleneck degradation the policy's
        detector currently sees vs. its armed reference — ``0.0`` when
        quiet, when the policy has no detector (static / oracle), or
        before any poll.  Side-effect-free: probing never advances
        detector state.
        """
        det = getattr(self.policy, "detector", None)
        if det is None or self.last_source is None:
            return 0.0
        return max(0.0, det.shift(self.config, self.last_source))

    def interference_active(self) -> bool:
        """True when the detector's current shift exceeds its trigger
        threshold — the replica-level "interference present" signal the
        ``odin_aware`` router keys on."""
        det = getattr(self.policy, "detector", None)
        if det is None or self.last_source is None:
            return False
        return self.interference_score() > det.rel_threshold

    def estimated_bottleneck(self) -> float:
        """Estimated bottleneck stage time of the committed config from
        the most recent polled time source (NaN before any poll) — the
        per-query service-time estimate routers cost replicas with."""
        if self.last_source is None:
            return float("nan")
        from repro.schedulers.base import bottleneck_time
        return bottleneck_time(self.config, self.last_source)

    def estimated_service_latency(self) -> float:
        """Estimated end-to-end (pipelined) latency of one query on the
        committed config from the most recent polled time source (NaN
        before any poll) — occupied stages × bottleneck beat, the
        latency estimate admission policies compare against an SLO
        (docs/CONTROL.md)."""
        if self.last_source is None:
            return float("nan")
        from repro.core.pipeline_state import pipelined_latency
        return pipelined_latency(self.last_source.stage_times(self.config))

    def poll(self, source: StageTimeSource) -> RuntimeStep:
        """Advance the state machine by one query."""
        self.last_source = source
        self._sync_mesh(source)
        if self.explorer is None:
            if not self.policy.detect(self.config, source):
                return RuntimeStep(list(self.config), serial=False,
                                   mesh=self._mesh_copy())
            if self.mesh is not None:
                self.explorer = self.policy.make_explorer(self.config,
                                                          mesh=self.mesh)
            else:
                self.explorer = self.policy.make_explorer(self.config)
            if self._serial_phase:
                self.num_rebalances += 1

        if not self._serial_phase:
            # Instant policy: commit within this poll; the query itself
            # runs pipelined on the new configuration.
            for _ in range(self.MAX_INSTANT_STEPS):
                if self.explorer.done:
                    break
                self.explorer.step(source)
            else:
                raise RuntimeError(
                    f"instant explorer {type(self.explorer).__name__} "
                    f"(policy {type(self.policy).__name__}) did not "
                    f"finish within {self.MAX_INSTANT_STEPS} steps")
            self._commit(source)
            return RuntimeStep(list(self.config), serial=False,
                               committed=True, mesh=self._mesh_copy())

        trial_mesh = None
        if self.mesh is not None:
            trial_mesh = list(getattr(self.explorer, "A", self.mesh))
        trial_cfg = self.explorer.step(source)
        if self.mesh is not None:
            # The step may itself have moved a device; report the
            # assignment the trial query actually runs with.
            trial_mesh = list(getattr(self.explorer, "A", trial_mesh))
        self._phase_steps += 1
        committed = False
        if self.explorer.done:
            self._commit(source)
            committed = True
        return RuntimeStep(list(trial_cfg), serial=True,
                           committed=committed, mesh=trial_mesh)

    def arm(self, source: StageTimeSource) -> None:
        """Prime detection with one observation, starting no phase.

        Drivers that cannot poll from the very first query (the live
        engine has no stage-time estimates until one query has been
        measured) call this once so 'now' becomes the detection
        baseline — the same thing the first ``poll``'s ``detect`` call
        does in the simulator.  Any trigger is discarded.
        """
        self.last_source = source
        self._sync_mesh(source)
        self.policy.detect(self.config, source)

    def reset(self, config: Optional[Sequence[int]] = None,
              mesh: Optional[Sequence[int]] = None) -> None:
        """Abandon any in-flight phase and re-arm the policy."""
        self.explorer = None
        self._phase_steps = 0
        self.last_source = None
        if config is not None:
            self.config = list(config)
        if mesh is not None:
            self.mesh = list(mesh)
        self.policy.reset()

    # -- internals -----------------------------------------------------------
    @property
    def _serial_phase(self) -> bool:
        return getattr(self.explorer, "serial", True)

    def _mesh_copy(self) -> Optional[List[int]]:
        return list(self.mesh) if self.mesh is not None else None

    def _sync_mesh(self, source: StageTimeSource) -> None:
        """Push the committed assignment onto mesh-aware time sources so
        single-argument ``stage_times(config)`` calls (detectors, the
        read-only estimators above) price the current slices."""
        if self.mesh is not None and hasattr(source, "assignment"):
            source.assignment = list(self.mesh)

    def _commit(self, source: StageTimeSource) -> None:
        res = self.explorer.result()
        if self._serial_phase:
            # Charge the serial queries the phase actually consumed, not
            # res.num_trials: explorer steps that could not apply a move
            # log no Trial but still serialized a query.
            self.total_trials += self._phase_steps
            self.mitigation_lengths.append(self._phase_steps)
        self.explorer = None
        self._phase_steps = 0
        self.config = list(res.config)
        res_mesh = getattr(res, "mesh", None)
        if self.mesh is not None and res_mesh is not None:
            if list(res_mesh) != list(self.mesh):
                self.num_mesh_resizes += 1
            self.mesh = list(res_mesh)
            self._sync_mesh(source)
        self.policy.finish(self.config, source)
