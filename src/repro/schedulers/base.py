"""Scheduler-policy protocols + the shared interference detector.

The ODIN paper treats its online rebalancer as one of several
interchangeable mitigation policies (ODIN vs. LLS vs. the exhaustive
oracle, §3.3–§4.2).  This module defines that contract:

* :class:`Explorer` — an in-progress rebalancing phase.  Each ``step()``
  produces the configuration one (serially processed) trial query runs
  with; ``done`` flips when the phase ends and ``result()`` reports the
  committed configuration plus the trial log.  Explorers whose steps do
  *not* cost a serial query (e.g. the DP oracle, which jumps straight to
  the optimum) set ``serial = False``.
* :class:`SchedulerPolicy` — decides *when* to rebalance (``detect``),
  builds the explorer that decides *how* (``make_explorer``), and is told
  when a phase commits (``finish``).  The shared
  :class:`~repro.schedulers.runtime.RebalanceRuntime` owns everything
  in between, so the simulator and the live JAX engine execute policies
  identically.
* :class:`InterferenceDetector` — the paper's §3.1 monitor (bottleneck
  stage time shifted beyond a relative threshold), factored out of the
  old per-controller copies, plus an EMA/hysteresis mode for noisy
  measured times.
"""
from __future__ import annotations

from typing import (
    TYPE_CHECKING,
    List,
    Optional,
    Protocol,
    Sequence,
    runtime_checkable,
)

if TYPE_CHECKING:  # annotation-only: keeps repro.core <-> schedulers acyclic
    from repro.core.odin import RebalanceResult
    from repro.core.pipeline_state import StageTimeSource


@runtime_checkable
class Explorer(Protocol):
    """One in-progress rebalancing phase; one ``step()`` per trial."""

    #: Whether each step consumes a serially-processed query (paper §4.2
    #: "Exploration overhead").  Instant policies (oracle) set False.
    serial: bool
    #: True once the phase has committed to a configuration.
    done: bool

    def step(self, source: StageTimeSource) -> List[int]:
        """Advance one trial; returns the configuration it runs with."""
        ...

    def result(self) -> RebalanceResult:
        """Committed configuration + trial log for the finished phase."""
        ...


@runtime_checkable
class SchedulerPolicy(Protocol):
    """A pluggable mitigation policy: decides, the runtime executes.

    Policies may additionally expose ``steady_detect_stable: bool``:
    True declares that ``detect`` has no side effects and returns a
    constant answer while (config, stage times) are unchanged — and
    stays quiet right after ``finish`` re-arms it.  The run loop's
    batch-granular fast path then polls once per environment-steady
    segment instead of once per query.  Absent (or False) keeps
    per-query polling, which is always correct.
    """

    def detect(self, config: Sequence[int], source: StageTimeSource) -> bool:
        """True if a rebalancing phase should start now."""
        ...

    def make_explorer(self, config: Sequence[int],
                      mesh: Optional[Sequence[int]] = None) -> Explorer:
        """Build the explorer that runs the phase from ``config``.

        ``mesh`` is the committed device assignment on sharded runs
        (docs/SHARDING.md) — the runtime passes it only when one is
        armed, so unsharded policies may ignore the kwarg entirely.
        """
        ...

    def finish(self, config: Sequence[int], source: StageTimeSource) -> None:
        """Phase committed to ``config``; re-arm detection state."""
        ...

    def reset(self) -> None:
        """Drop all online state (fresh serving window)."""
        ...


def bottleneck_time(config: Sequence[int], source: StageTimeSource) -> float:
    """Execution time of the slowest *non-empty* stage."""
    times = source.stage_times(config)
    return max(float(times[i]) for i, c in enumerate(config) if c > 0)


class InterferenceDetector:
    """Shared bottleneck-shift detector (paper §3.1).

    ``mode="rel"`` is the paper's rule: trigger when the bottleneck stage
    time moved beyond ``rel_threshold`` relative to the reference recorded
    at the end of the last rebalancing phase (up = interference arrived;
    down = it left).  The first observation records the reference.

    ``mode="ema"`` targets noisy *measured* times (live engine): the
    reference is an exponential moving average of observed bottlenecks and
    a trigger requires ``hysteresis`` consecutive out-of-band
    observations, debouncing one-query timing spikes that would otherwise
    burn a full exploration phase of serial queries.
    """

    MODES = ("rel", "ema")

    def __init__(self, rel_threshold: float = 0.02, mode: str = "rel",
                 ema_beta: float = 0.3, hysteresis: int = 2):
        if mode not in self.MODES:
            raise ValueError(f"unknown detector mode {mode!r}; "
                             f"expected one of {self.MODES}")
        self.rel_threshold = rel_threshold
        self.mode = mode
        self.ema_beta = ema_beta
        self.hysteresis = max(1, int(hysteresis))
        self._ref: Optional[float] = None
        self._streak = 0

    @property
    def steady_stable(self) -> bool:
        """Whether repeated quiet observations are side-effect-free.

        The paper's ``rel`` rule is a pure comparison against the
        post-rebalance reference, so skipping redundant observations in
        an unchanged environment cannot alter any later decision.  The
        EMA mode folds every quiet observation into the reference, so
        it must see each query."""
        return self.mode == "rel"

    @property
    def armed(self) -> bool:
        """Whether a reference bottleneck has been recorded yet."""
        return self._ref is not None

    def shift(self, config: Sequence[int],
              source: StageTimeSource) -> float:
        """Signed relative bottleneck shift vs. the armed reference,
        **without touching detector state** — ``> 0`` means the current
        bottleneck is slower than the post-rebalance reference.

        This is the read-only probe the cluster's interference-aware
        router uses to ask "does this replica's detector currently see
        interference?" between rebalances (docs/CLUSTER.md); ``0.0``
        before the first observation arms the reference.
        """
        if self._ref is None:
            return 0.0
        b = bottleneck_time(config, source)
        return (b - self._ref) / max(self._ref, 1e-12)

    def observe(self, config: Sequence[int],
                source: StageTimeSource) -> bool:
        """One monitoring observation; True if rebalancing should start."""
        b = bottleneck_time(config, source)
        if self._ref is None:
            self._ref = b
            return False
        rel = abs(b - self._ref) / max(self._ref, 1e-12)
        if self.mode == "rel":
            return rel > self.rel_threshold
        # EMA/hysteresis: trigger only on a sustained shift.  Out-of-band
        # observations are NOT folded into the average — a one-query
        # spike must not drag the reference enough that the *return* to
        # normal reads as a second shift.
        if rel > self.rel_threshold:
            self._streak += 1
            if self._streak >= self.hysteresis:
                self._streak = 0
                return True
            return False
        self._streak = 0
        self._ref = (1.0 - self.ema_beta) * self._ref + self.ema_beta * b
        return False

    def rearm(self, config: Sequence[int], source: StageTimeSource) -> None:
        """Record the post-rebalance bottleneck as the new reference."""
        self._ref = bottleneck_time(config, source)
        self._streak = 0

    def reset(self) -> None:
        self._ref = None
        self._streak = 0
