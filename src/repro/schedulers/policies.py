"""Built-in mitigation policies: odin, lls, oracle, none, hybrid.

Each policy pairs the shared :class:`InterferenceDetector` with an
explorer.  The exploration *algorithms* stay where the paper transcribed
them (``repro.core.odin`` / ``repro.core.lls``); this module is the
policy layer the registry exposes:

* ``odin``   — paper Algorithm 1 (plateau-escaping exploration).
* ``lls``    — Least-Loaded Scheduling baseline (§3.3).
* ``oracle`` — DP optimal partition, applied instantly (zero serial
  queries); the caller supplies the solver (the simulator wires its
  database-backed DP in, a live deployment can plug an estimator).
* ``none``   — static pipeline, never rebalances.
* ``hybrid`` — beyond-paper: LLS's cheap greedy move first; if the phase
  plateaus, escalate to ODIN exploration from the best config so far.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Union

from repro.core.lls import LLSExplorer
from repro.core.odin import MeshOdinExplorer, OdinExplorer, RebalanceResult
from repro.core.pipeline_state import StageTimeSource, throughput
from repro.schedulers.base import InterferenceDetector
from repro.schedulers.defaults import DEFAULT_ALPHA, resolve_rel_threshold
from repro.schedulers.registry import register_scheduler

DetectorSpec = Union[InterferenceDetector, str, None]


def _make_detector(detector: DetectorSpec,
                   rel_threshold: Optional[float]) -> InterferenceDetector:
    rel_threshold = resolve_rel_threshold(rel_threshold)
    if isinstance(detector, InterferenceDetector):
        return detector
    if isinstance(detector, str):
        return InterferenceDetector(rel_threshold=rel_threshold,
                                    mode=detector)
    return InterferenceDetector(rel_threshold=rel_threshold)


class _DetectorPolicy:
    """Common detect/finish/reset around the shared detector.

    ``rel_threshold=None`` resolves to the repo-wide
    :data:`~repro.schedulers.defaults.DEFAULT_REL_THRESHOLD` so the
    simulator and the live engine agree by construction.
    """

    def __init__(self, rel_threshold: Optional[float] = None,
                 detector: DetectorSpec = None):
        self.detector = _make_detector(detector, rel_threshold)

    @property
    def steady_detect_stable(self) -> bool:
        """Fast-path contract (see ``SchedulerPolicy``): the paper's
        pure ``rel`` rule tolerates one poll per steady segment; the
        stateful EMA mode must observe every query."""
        return self.detector.steady_stable

    def detect(self, config: Sequence[int],
               source: StageTimeSource) -> bool:
        return self.detector.observe(config, source)

    def finish(self, config: Sequence[int],
               source: StageTimeSource) -> None:
        self.detector.rearm(config, source)

    def reset(self) -> None:
        self.detector.reset()


@register_scheduler("odin")
class OdinPolicy(_DetectorPolicy):
    """Paper Algorithm 1 behind the shared detector."""

    def __init__(self, alpha: int = DEFAULT_ALPHA,
                 rel_threshold: Optional[float] = None,
                 detector: DetectorSpec = None):
        super().__init__(rel_threshold, detector)
        self.alpha = alpha

    def make_explorer(self, config: Sequence[int],
                      mesh: Optional[Sequence[int]] = None) -> OdinExplorer:
        if mesh is not None:
            # Sharded run: explore the (boundary, slice) action space
            # (docs/SHARDING.md).
            return MeshOdinExplorer(config, self.alpha, mesh)
        return OdinExplorer(config, self.alpha)


@register_scheduler("lls")
class LLSPolicy(_DetectorPolicy):
    """Least-Loaded Scheduling baseline behind the shared detector."""

    def __init__(self, rel_threshold: Optional[float] = None,
                 max_moves: int = 64,
                 detector: DetectorSpec = None):
        super().__init__(rel_threshold, detector)
        self.max_moves = max_moves

    def make_explorer(self, config: Sequence[int],
                      mesh: Optional[Sequence[int]] = None) -> LLSExplorer:
        # LLS stays a boundary-only baseline: on sharded runs it explores
        # layer moves on the *fixed* committed assignment (the runtime
        # keeps pricing trials with the current slices), which is exactly
        # the boundary-only reference the sharding benchmarks compare
        # ODIN's (boundary, slice) moves against.
        return LLSExplorer(config, self.max_moves)


@register_scheduler("none")
class StaticPolicy:
    """Static pipeline: never rebalances (the paper's 'no mitigation')."""

    steady_detect_stable = True

    def detect(self, config: Sequence[int],
               source: StageTimeSource) -> bool:
        return False

    def make_explorer(self, config: Sequence[int],
                      mesh: Optional[Sequence[int]] = None):
        raise RuntimeError("static policy never explores")

    def finish(self, config: Sequence[int],
               source: StageTimeSource) -> None:
        pass

    def reset(self) -> None:
        pass


class OracleExplorer:
    """Jumps straight to the solver's configuration; costs no queries."""

    serial = False

    def __init__(self, target: Sequence[int],
                 mesh: Optional[Sequence[int]] = None):
        self.target = list(target)
        self.mesh = list(mesh) if mesh is not None else None
        self.done = False

    def step(self, source: StageTimeSource) -> List[int]:
        self.done = True
        return list(self.target)

    def result(self) -> RebalanceResult:
        mesh = list(self.mesh) if self.mesh is not None else None
        return RebalanceResult(list(self.target), 0.0, [], mesh=mesh)


@register_scheduler("oracle")
class OraclePolicy:
    """Optimal-partition oracle as a normal (instant) policy.

    ``solver(config, source) -> config`` returns the best configuration
    for the *current* interference state — the simulator passes its
    DP-over-database solver (paper's exhaustive search, §4.3).  Because
    the optimum is recomputed on every detect, no bottleneck-threshold
    detector is needed: detection is simply "the optimum moved".

    Sharded runs wire a *mesh-aware* solver instead, returning a
    ``(config, assignment)`` pair (``repro.core.exhaustive.
    optimal_partition_mesh``); detection then fires when either the
    boundary optimum or the slice optimum moved, compared against the
    committed assignment the runtime synced onto the time source.
    """

    # Detect recomputes the optimum from (config, current stage times)
    # and commits instantly when it moves, so under an unchanged
    # environment one poll answers for the whole segment.
    steady_detect_stable = True

    def __init__(self, solver: Callable[[Sequence[int], StageTimeSource],
                                        Sequence[int]]):
        self.solver = solver
        self._pending: Optional[tuple] = None   # (config, assignment|None)

    def detect(self, config: Sequence[int],
               source: StageTimeSource) -> bool:
        opt = self.solver(config, source)
        if (isinstance(opt, tuple) and len(opt) == 2
                and isinstance(opt[0], (list, tuple))):
            # Mesh-aware solver: (config, assignment).
            cfg, assign = list(opt[0]), list(opt[1])
            cur = getattr(source, "assignment", None)
            if cfg != list(config) or (cur is not None
                                       and assign != list(cur)):
                self._pending = (cfg, assign)
                return True
            return False
        opt = list(opt)
        if opt != list(config):
            self._pending = (opt, None)
            return True
        return False

    def make_explorer(self, config: Sequence[int],
                      mesh: Optional[Sequence[int]] = None) -> OracleExplorer:
        if self._pending is not None:
            target, assign = self._pending
        else:
            target = list(config)
            assign = list(mesh) if mesh is not None else None
        self._pending = None
        return OracleExplorer(target, mesh=assign)

    def finish(self, config: Sequence[int],
               source: StageTimeSource) -> None:
        pass

    def reset(self) -> None:
        self._pending = None


class HybridExplorer:
    """LLS first move(s); ODIN exploration if the phase plateaus.

    LLS converges in ~1 serial query but gets stuck on the lumpy
    layer-cost profiles where single greedy moves cannot help (the
    motivation for ODIN's plateau escape, §3.3).  The hybrid phase runs
    LLS to its stopping point; if that recovered less than
    ``plateau_margin`` relative throughput, it escalates to ODIN seeded
    with the best configuration seen so far.  Cheap when LLS suffices,
    ODIN-strength when it does not.
    """

    serial = True

    def __init__(self, config: Sequence[int], alpha: int,
                 plateau_margin: float = 0.01, max_moves: int = 64):
        self._config0 = list(config)
        self.alpha = alpha
        self.plateau_margin = plateau_margin
        self._lls = LLSExplorer(config, max_moves)
        self._odin: Optional[OdinExplorer] = None
        self._t0: Optional[float] = None
        # Best (config, throughput) measured during the LLS phase.  LLS
        # itself keeps its observed-degrading last move (paper §3.3);
        # hybrid is free to revert to the best configuration it already
        # measured — committing a config costs nothing.
        self._best: Optional[tuple] = None
        self.done = False

    def step(self, source: StageTimeSource) -> List[int]:
        assert not self.done
        if self._t0 is None:
            self._t0 = throughput(source.stage_times(self._config0))
            self._best = (list(self._config0), self._t0)
        if self._odin is None:
            cfg = self._lls.step(source)
            if self._lls.trials and self._lls.trials[-1].throughput > \
                    self._best[1]:
                tr = self._lls.trials[-1]
                self._best = (list(tr.config), tr.throughput)
            if self._lls.done:
                if self._best[1] > self._t0 * (1.0 + self.plateau_margin):
                    self.done = True
                else:
                    self._odin = OdinExplorer(self._best[0], self.alpha)
            return cfg
        cfg = self._odin.step(source)
        self.done = self._odin.done
        return cfg

    def result(self) -> RebalanceResult:
        lls_res = self._lls.result()
        best_cfg, best_T = self._best if self._best is not None else (
            list(self._config0), 0.0)
        trials = list(lls_res.trials)
        if self._odin is not None:
            odin_res = self._odin.result()
            trials += odin_res.trials
            if odin_res.throughput > best_T:
                best_cfg, best_T = list(odin_res.config), odin_res.throughput
        return RebalanceResult(list(best_cfg), best_T, trials)


@register_scheduler("hybrid")
class HybridPolicy(_DetectorPolicy):
    """Beyond-paper policy: LLS's cheap move, ODIN's escape hatch."""

    def __init__(self, alpha: int = DEFAULT_ALPHA,
                 rel_threshold: Optional[float] = None,
                 plateau_margin: float = 0.01, max_moves: int = 64,
                 detector: DetectorSpec = None):
        super().__init__(rel_threshold, detector)
        self.alpha = alpha
        self.plateau_margin = plateau_margin
        self.max_moves = max_moves

    def make_explorer(self, config: Sequence[int],
                      mesh: Optional[Sequence[int]] = None) -> HybridExplorer:
        # Like LLS, hybrid explores layer moves on the fixed committed
        # assignment (boundary-only on sharded runs).
        return HybridExplorer(config, self.alpha,
                              plateau_margin=self.plateau_margin,
                              max_moves=self.max_moves)
