"""String-keyed scheduler registry.

Every mitigation policy is constructed through ``make_scheduler(name,
**kwargs)`` — the simulator, the live engine, benchmarks and CLI drivers
share one construction path and contain no per-scheduler dispatch.

Registration is a decorator::

    @register_scheduler("my-policy")
    class MyPolicy:
        def detect(self, config, source): ...
        def make_explorer(self, config): ...
        def finish(self, config, source): ...
        def reset(self): ...

Callers pass a superset of keyword arguments (``alpha`` means nothing to
LLS, ``solver`` means nothing to ODIN); ``make_scheduler`` filters them
against the policy's ``__init__`` signature so one call site can build
any registered policy.  *Required* parameters a caller omits still raise
(e.g. ``oracle`` without a ``solver``).
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, List, Tuple, Type


_REGISTRY: Dict[str, Tuple[Type, dict]] = {}


def register_scheduler(name: str, **defaults) -> Callable[[Type], Type]:
    """Class decorator registering a SchedulerPolicy under ``name``.

    ``defaults`` are keyword arguments merged (at lower priority) into
    every ``make_scheduler(name, ...)`` call — useful for registering one
    class under several tunings.
    """
    def deco(cls: Type) -> Type:
        if name in _REGISTRY:
            raise ValueError(f"scheduler {name!r} already registered "
                             f"({_REGISTRY[name][0].__qualname__})")
        _REGISTRY[name] = (cls, dict(defaults))
        # Stamp the registered name unless the class itself (not a base)
        # already declares one.
        if not cls.__dict__.get("name"):
            cls.name = name
        return cls
    return deco


def unregister_scheduler(name: str) -> None:
    """Remove a registration (tests / plugin reload)."""
    _REGISTRY.pop(name, None)


def _ensure_builtins() -> None:
    # Importing the module runs its @register_scheduler decorators; lazy
    # so registry.py itself stays import-cycle-free.
    from repro.schedulers import policies  # noqa: F401


def available_schedulers() -> List[str]:
    """Sorted names of every registered policy."""
    _ensure_builtins()
    return sorted(_REGISTRY)


def scheduler_class(name: str) -> Type:
    _ensure_builtins()
    try:
        return _REGISTRY[name][0]
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; available: "
                         f"{available_schedulers()}") from None


def make_scheduler(name: str, **kwargs):
    """Construct the policy registered under ``name``.

    Keyword arguments the policy's ``__init__`` does not accept are
    dropped (callers pass one superset for all policies); missing
    *required* arguments still raise ``TypeError``.
    """
    _ensure_builtins()
    if name not in _REGISTRY:
        raise ValueError(f"unknown scheduler {name!r}; available: "
                         f"{available_schedulers()}")
    cls, defaults = _REGISTRY[name]
    merged = {**defaults, **kwargs}
    if cls.__init__ is object.__init__:
        merged = {}
    else:
        sig = inspect.signature(cls.__init__)
        params = sig.parameters.values()
        if not any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
            accepted = {p.name for p in params}
            merged = {k: v for k, v in merged.items() if k in accepted}
    return cls(**merged)
