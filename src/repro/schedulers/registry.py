"""String-keyed scheduler registry.

Every mitigation policy is constructed through ``make_scheduler(name,
**kwargs)`` — the simulator, the live engine, benchmarks and CLI drivers
share one construction path and contain no per-scheduler dispatch.

Registration is a decorator::

    @register_scheduler("my-policy")
    class MyPolicy:
        def detect(self, config, source): ...
        def make_explorer(self, config): ...
        def finish(self, config, source): ...
        def reset(self): ...

Callers pass a superset of keyword arguments (``alpha`` means nothing to
LLS, ``solver`` means nothing to ODIN); ``make_scheduler`` filters them
against the policy's ``__init__`` signature so one call site can build
any registered policy.  *Required* parameters a caller omits still raise
(e.g. ``oracle`` without a ``solver``).

The mechanism itself is :class:`repro.util.Registry`, shared with the
workload-generator registry (``repro.workloads.registry``).
"""
from __future__ import annotations

from typing import Callable, List, Type

from repro.util.registry import Registry

# Importing the policies module runs its @register_scheduler decorators;
# lazy so registry.py itself stays import-cycle-free.
_REGISTRY = Registry("scheduler", builtins_module="repro.schedulers.policies")


def register_scheduler(name: str, **defaults) -> Callable[[Type], Type]:
    """Class decorator registering a SchedulerPolicy under ``name``.

    ``defaults`` are keyword arguments merged (at lower priority) into
    every ``make_scheduler(name, ...)`` call — useful for registering one
    class under several tunings.
    """
    return _REGISTRY.register(name, **defaults)


def unregister_scheduler(name: str) -> None:
    """Remove a registration (tests / plugin reload)."""
    _REGISTRY.unregister(name)


def available_schedulers() -> List[str]:
    """Sorted names of every registered policy."""
    return _REGISTRY.available()


def scheduler_class(name: str) -> Type:
    return _REGISTRY.cls(name)


def make_scheduler(name: str, **kwargs):
    """Construct the policy registered under ``name``.

    Keyword arguments the policy's ``__init__`` does not accept are
    dropped (callers pass one superset for all policies); missing
    *required* arguments still raise ``TypeError``.
    """
    return _REGISTRY.make(name, **kwargs)
