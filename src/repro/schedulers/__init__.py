"""Pluggable scheduler-policy subsystem (see docs/SCHEDULERS.md).

Policies implement :class:`SchedulerPolicy`, are constructed by name via
:func:`make_scheduler`, and are executed by the :class:`RebalanceRuntime`
shared by the simulator and the live serving engine.
"""
from repro.schedulers.base import (  # noqa: F401
    Explorer,
    InterferenceDetector,
    SchedulerPolicy,
    bottleneck_time,
)
from repro.schedulers.defaults import (  # noqa: F401
    DEFAULT_ALPHA,
    DEFAULT_REL_THRESHOLD,
    MEASURED_DETECTOR_MODE,
    resolve_rel_threshold,
)
from repro.schedulers.registry import (  # noqa: F401
    available_schedulers,
    make_scheduler,
    register_scheduler,
    scheduler_class,
    unregister_scheduler,
)
from repro.schedulers.runtime import (  # noqa: F401
    RebalanceRuntime,
    RuntimeStep,
)
from repro.schedulers.policies import (  # noqa: F401
    HybridExplorer,
    HybridPolicy,
    LLSPolicy,
    OdinPolicy,
    OracleExplorer,
    OraclePolicy,
    StaticPolicy,
)
