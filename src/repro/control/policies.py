"""Built-in admission policies: none, queue_cap, slo_shed, value_shed,
adaptive_batch.

All decisions are pure functions of the :class:`AdmissionView` and the
policy's own (deterministic) state, so a run is reproducible from
``(workload, seed, scheduler, admission)`` alone.

* ``none`` — admit everything; declared ``admits_all`` so the run loop
  skips the admission checks entirely and closed-loop traces stay
  bit-identical to a run with no control plane at all.
* ``queue_cap`` — classic bounded-queue shedding: shed when the
  predicted backlog (in queries) has reached ``cap``.  The blunt
  baseline every serving system ships first.
* ``slo_shed`` — SLO-aware shedding (InferLine-style): shed when the
  predicted queueing delay plus the runtime's estimated end-to-end
  service latency would already breach the latency objective.  A query
  that cannot meet its SLO only delays the ones behind it.
* ``value_shed`` — expected-value shedding over QoS tiers
  (docs/QOS.md): admit iff ``value x predicted attainment >= theta``,
  so high-value traffic survives deeper overload than best-effort
  traffic instead of everyone shedding at the same queue depth.
* ``adaptive_batch`` — never sheds; instead shrinks the run loop's
  batch/chunk bound as the observed p99 queueing delay approaches the
  SLO and grows it back while the tail is comfortable (batching
  amortizes dispatch overhead but adds head-of-line wait under load).

Closed loops never shed under ``queue_cap`` / ``slo_shed``: the
predicted wait is zero by construction, so every decision reduces to
"is one service beat within the objective" — true for any feasible SLO
(tests/test_control.py pins the bit-identity with ``none``).
"""
from __future__ import annotations

import math
from collections import deque

import numpy as np

from repro.control.base import AdmissionView
from repro.control.registry import register_admission


@register_admission("none")
class AdmitAll:
    """Admit every arrival (the default; control plane disabled)."""

    admits_all = True

    def admit(self, view: AdmissionView) -> bool:
        return True

    def reset(self) -> None:
        pass


@register_admission("queue_cap")
class QueueCapAdmission:
    """Shed when the predicted backlog reaches ``cap`` queries.

    The backlog is estimated as predicted wait / estimated service
    beat (:attr:`AdmissionView.queue_length`), so the same decision is
    computable in the scalar tick and in the vectorized ledger's
    chunk admission pre-pass.  While the beat is still unknown (live
    engine before its first measurement) everything is admitted.
    """

    admits_all = False

    def __init__(self, cap: int = 64):
        if cap < 1:
            raise ValueError(f"queue_cap needs cap >= 1, got {cap}")
        self.cap = int(cap)

    def admit(self, view: AdmissionView) -> bool:
        return view.queue_length < self.cap

    def reset(self) -> None:
        pass


@register_admission("slo_shed")
class SloShedAdmission:
    """Shed when the predicted latency would breach the SLO.

    Admits iff ``wait + margin * est_latency <= slo``: the query's
    predicted queueing delay plus (a safety multiple of) the estimated
    end-to-end service latency must fit inside the latency objective.
    ``margin > 1`` sheds earlier, buying headroom against estimate
    noise on the live engine (measured times jitter query to query);
    it is a multiple of the service estimate, so the knob is
    model-independent.

    With exact estimates (the simulator's steady chunks) every
    admitted query's latency is ``<= slo`` by construction, which is
    what the control-smoke CI gate pins: p99-of-admitted meets the SLO
    under an overload where ``none`` blows through it.
    """

    admits_all = False

    def __init__(self, slo: float, margin: float = 1.0):
        if not slo > 0.0:
            raise ValueError(f"slo_shed needs slo > 0, got {slo}")
        if margin < 0.0:
            raise ValueError(f"margin must be >= 0, got {margin}")
        self.slo = float(slo)
        self.margin = float(margin)

    def admit(self, view: AdmissionView) -> bool:
        est = view.est_latency
        if not math.isfinite(est):
            est = view.est_service
        if not math.isfinite(est):
            est = 0.0
        return view.wait + self.margin * est <= self.slo

    def reset(self) -> None:
        pass


@register_admission("value_shed")
class ValueShedAdmission:
    """Shed by *expected value*, not binary feasibility (docs/QOS.md).

    Estimates the probability the arrival would still meet its
    deadline if admitted now, as a linear ramp in the predicted wait:
    attainment is 1 while ``wait + est_latency <= deadline``, 0 once
    the wait alone has consumed the deadline, and
    ``(deadline - wait) / est_latency`` in between.  The query is
    admitted iff ``value x attainment >= theta``.

    Against tier-blind ``slo_shed`` the difference is exactly the
    QoS premise: a value-10 query is still worth serving at a 10%
    attainment estimate (expected value 1.0 >= theta), while a
    value-1 best-effort query sheds as soon as its odds dip below
    ``theta`` — under overload the cheap traffic clears the queue for
    the valuable traffic instead of starving it blindly.

    Queries without a deadline (``view.deadline`` infinite) fall back
    to the constructor ``slo`` if one is given, else their attainment
    estimate is 1 and they are admitted whenever ``value >= theta``.
    Pure function of the view, so the chunked admission pre-pass and
    the scalar tick decide identically.
    """

    admits_all = False

    def __init__(self, theta: float = 0.5, slo: float = 0.0):
        if not theta > 0.0:
            raise ValueError(f"value_shed needs theta > 0, got {theta}")
        if slo < 0.0:
            raise ValueError(f"slo must be >= 0, got {slo}")
        self.theta = float(theta)
        self.slo = float(slo)

    def expected_value(self, view: AdmissionView) -> float:
        """``value x estimated attainment`` for this arrival."""
        deadline = view.deadline
        if not math.isfinite(deadline) and self.slo > 0.0:
            deadline = self.slo
        if not math.isfinite(deadline):
            return view.value
        est = view.est_latency
        if not math.isfinite(est):
            est = view.est_service
        if not math.isfinite(est) or est <= 0.0:
            attain = 1.0 if view.wait <= deadline else 0.0
        else:
            attain = min(1.0, max(0.0, (deadline - view.wait) / est))
        return view.value * attain

    def admit(self, view: AdmissionView) -> bool:
        return self.expected_value(view) >= self.theta

    def reset(self) -> None:
        pass


@register_admission("adaptive_batch")
class AdaptiveBatchAdmission:
    """SLO-aware ``max_batch`` control: admit everything, steer batching.

    Maintains a rolling window of observed queueing delays; every
    ``interval`` observations the window's p99 is compared against the
    SLO: above ``high * slo`` the batch bound halves (head-of-line
    wait inside big batches is eating the budget), below ``low * slo``
    it doubles (amortization is free).  The bound always stays within
    ``[min_batch, max_batch]`` (property-tested across bursty seeds).

    The run loop also reports each query's dispatch *occupancy* (how
    many queries rode its batch — the formed-dispatch paths fill this
    in; scalar paths report 1).  Occupancy is batch-awareness for the
    widen branch: when the rolling mean shows dispatches saturating the
    current bound while the SLO has headroom, the bound provably binds
    and re-opens at double speed (x4 per interval instead of x2).
    Shrink decisions are occupancy-blind — overload must collapse the
    bound whether or not batches were forming.

    Declared ``admits_all``: the run loop skips shed checks and only
    consults :meth:`max_chunk_bound` / :meth:`observe`, so closed-loop
    results stay bit-identical (closed loops have zero queue delay and
    the bound is a pure computational cap for the simulator's chunks).
    """

    admits_all = True

    def __init__(
        self,
        slo: float,
        min_batch: int = 1,
        max_batch: int = 32,
        window: int = 64,
        interval: int = 16,
        low: float = 0.5,
        high: float = 0.9,
    ):
        if not slo > 0.0:
            raise ValueError(f"adaptive_batch needs slo > 0, got {slo}")
        if not 1 <= min_batch <= max_batch:
            raise ValueError(
                f"need 1 <= min_batch <= max_batch, got "
                f"[{min_batch}, {max_batch}]"
            )
        if not 0.0 <= low < high:
            raise ValueError(f"need 0 <= low < high, got [{low}, {high}]")
        self.slo = float(slo)
        self.min_batch = int(min_batch)
        self.max_batch = int(max_batch)
        self.window = max(1, int(window))
        self.interval = max(1, int(interval))
        self.low = float(low)
        self.high = float(high)
        self._delays: deque = deque(maxlen=self.window)
        self._occ: deque = deque(maxlen=self.window)
        self._since_update = 0
        self._bound = self.max_batch

    def admit(self, view: AdmissionView) -> bool:
        return True

    def max_chunk_bound(self) -> int:
        """Current batch/chunk bound, in ``[min_batch, max_batch]``."""
        return self._bound

    def observe(self, queue_delay: float, service_latency: float,
                occupancy: float = 1.0) -> None:
        self._delays.append(queue_delay)
        self._occ.append(occupancy)
        self._since_update += 1
        if self._since_update < self.interval:
            return
        self._since_update = 0
        p99 = float(np.percentile(np.asarray(self._delays), 99))
        if p99 > self.high * self.slo:
            self._bound = max(self.min_batch, self._bound // 2)
        elif p99 < self.low * self.slo:
            occ = float(np.mean(np.asarray(self._occ)))
            step = 4 if occ >= 0.75 * self._bound else 2
            self._bound = min(self.max_batch, self._bound * step)

    def reset(self) -> None:
        self._delays.clear()
        self._occ.clear()
        self._since_update = 0
        self._bound = self.max_batch
