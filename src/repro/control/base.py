"""Admission-control & autoscaler protocols (the SLO control plane).

ODIN's rebalancing keeps a pipeline as fast as the interference allows,
but it cannot make offered load fit capacity: when an open-loop
workload outruns the (rebalanced) pipeline, the arrival queue grows
without bound and every latency percentile is lost.  The control plane
is the layer around the scheduler that closes that loop — InferLine's
thesis (provision/control around the planner) combined with Strait's
(interference signals should shape admission, not just placement):

* An :class:`AdmissionPolicy` decides, per arrival, whether the query
  enters the pipeline at all.  Shed queries never execute, never poll
  the scheduler, and are reported separately so SLO attainment is
  measured on *admitted goodput*.
* An :class:`Autoscaler` decides, per fleet arrival, which replicas of
  a :class:`~repro.cluster.Cluster` are active — routers only ever see
  the active set, so draining a replica simply stops feeding it.

Both are pluggable through string-keyed registries mirroring
``repro.schedulers`` / ``repro.workloads`` / ``repro.cluster``
(:mod:`repro.control.registry`).  See docs/CONTROL.md.
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING,
    Optional,
    Protocol,
    Sequence,
    runtime_checkable,
)

if TYPE_CHECKING:  # annotation-only: keeps control <-> cluster acyclic
    from repro.cluster.base import ReplicaView


@dataclasses.dataclass(frozen=True)
class AdmissionView:
    """What an admission decision sees, before the query executes.

    Built by the run loop (single pipeline) or the cluster (from the
    routed replica's view) from state the schedulers subsystem already
    maintains — the admission-head ledger and the runtime's estimated
    bottleneck.  Everything is an *estimate at decision time*: a shed
    query never executes, so its true service time is never known.
    """

    #: Global (fleet) index of the arriving query.
    query: int
    #: Arrival time in driver units; ``None`` for a closed loop, where
    #: queries arrive exactly when the pipeline can take them and the
    #: predicted wait is zero by construction.
    arrival: Optional[float]
    #: Predicted admission-head wait (queueing delay) the query would
    #: see if admitted now.  Zero for closed loops.
    wait: float
    #: Estimated per-query service beat on the committed configuration
    #: (the runtime's ``estimated_bottleneck()``) — the rate at which
    #: the admission head drains; NaN before the scheduler has been
    #: polled at least once.
    est_service: float
    #: Estimated end-to-end latency of one query on the committed
    #: configuration (the runtime's ``estimated_service_latency()``:
    #: occupied stages x bottleneck beat); NaN before the first poll.
    est_latency: float = float("nan")
    #: QoS tier index of the arrival (``repro.qos``); ``None`` when the
    #: run has no tiers configured.  The remaining QoS fields default
    #: to "one anonymous tier of unit value with no deadline", so
    #: tier-blind policies and pre-QoS call sites are unaffected.
    tier: Optional[int] = None
    #: Priority class (higher preempts lower at batch formation).
    priority: int = 0
    #: Relative deadline in seconds from arrival (``inf`` = none).
    deadline: float = float("inf")
    #: SLO value: what completing this query within deadline is worth.
    value: float = 1.0

    @property
    def queue_length(self) -> float:
        """Predicted backlog in *queries*: the wait divided by the
        estimated service beat (0.0 while the beat is unknown)."""
        if not self.est_service > 0.0:  # NaN or zero -> unknown
            return 0.0
        return self.wait / self.est_service


@runtime_checkable
class AdmissionPolicy(Protocol):
    """Per-arrival admit/shed decision.

    Implementations may additionally expose:

    * ``admits_all: bool`` — class-level declaration that ``admit``
      always returns True.  The run loop then skips the shed checks
      entirely, keeping closed-loop traces bit-identical to running
      with no policy at all (the ``none`` built-in).
    * ``observe(queue_delay, service_latency)`` — called once per
      *executed* query with its measured queueing delay and service
      time; how feedback controllers (``adaptive_batch``) track the
      tail they are steering.
    * ``max_chunk_bound() -> int`` — a live upper bound on the run
      loop's chunk/batch size; consulted at every chunk formation.
    * ``slo: float`` — the latency objective (driver time units) the
      policy enforces; stamped onto the finished trace so SLO
      attainment is computed against the same target.

    ``admit`` must be a pure function of the view (plus constructor
    state): the run loop's chunked fast path calls it with *predicted*
    views to find chunk cut points and re-decides the cut query
    against the actual ledger, so a policy whose answer depends on how
    often it was asked (a call-counting rate limiter, say) would
    diverge between the chunked and scalar paths.  Track history
    through ``observe`` — called exactly once per executed query —
    instead.
    """

    def admit(self, view: AdmissionView) -> bool:
        """True to admit the arrival, False to shed it."""
        ...

    def reset(self) -> None:
        """Drop online state (fresh serving window)."""
        ...


@runtime_checkable
class Autoscaler(Protocol):
    """Decides which replicas of a fleet are active, per arrival.

    ``views`` always covers the *whole* fleet (the autoscaler must see
    drained replicas to re-activate them); the returned indices select
    the subset routers may dispatch to.  Implementations must be
    deterministic given their state and the views, and must return at
    least one index.
    """

    def active(self, q: int, now: float, views: Sequence[ReplicaView]) -> Sequence[int]:
        """Fleet indices of the replicas active for arrival ``q``."""
        ...

    def reset(self) -> None:
        """Drop scaling state (fresh serving window)."""
        ...
