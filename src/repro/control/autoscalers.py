"""Built-in autoscalers: static, load_profile.

* ``static`` — the fleet you built is the fleet you run: all replicas
  (or a fixed prefix) stay active for the whole window.  The default,
  and bit-identical to pre-control-plane cluster runs.
* ``load_profile`` — sizes the active set off the rolling offered load
  (the same offered-vs-achieved signal ``ClusterTrace.load_profile``
  reports post-hoc, measured online): the estimated arrival rate times
  the estimated per-query service beat, divided by a target
  utilization, is the number of replicas the fleet needs.  Backlog
  growth (offered outrunning achieved) forces a scale-out even when
  the rate estimate lags a burst, and — Strait's argument —
  a replica whose detector currently reports interference is treated
  as lost capacity: the autoscaler scales *out* around it (and prefers
  draining it) instead of letting the router keep feeding it.

Autoscalers are deterministic: same views, same state, same answer —
cluster runs stay reproducible from
``(workload, seed, scheduler, router, autoscaler)`` alone.
"""
from __future__ import annotations

import math
from collections import deque
from typing import List, Optional, Sequence

from repro.control.registry import register_autoscaler


@register_autoscaler("static")
class StaticAutoscaler:
    """All replicas (or the first ``n_active``) active, always."""

    def __init__(self, n_active: Optional[int] = None):
        if n_active is not None and n_active < 1:
            raise ValueError(f"n_active must be >= 1, got {n_active}")
        self.n_active = n_active

    def active(self, q: int, now: float, views) -> Sequence[int]:
        n = len(views)
        k = n if self.n_active is None else min(self.n_active, n)
        return range(k)

    def reset(self) -> None:
        pass


@register_autoscaler("load_profile")
class LoadProfileAutoscaler:
    """Activate/drain replicas off the rolling offered-load profile.

    Per fleet arrival (recomputed every ``interval`` arrivals):

    1. *Offered rate* — arrivals in the rolling ``window`` divided by
       their time span.
    2. *Demand* — ``ceil(rate * beat / target_util)`` replicas, with
       ``beat`` the median estimated service beat across replicas
       (each replica's ``RebalanceRuntime.estimated_bottleneck()``).
    3. *Achieved pressure* — if the mean in-system backlog per active
       replica exceeds ``backlog_per_replica``, offered load has been
       outrunning achieved throughput regardless of what the rate
       estimate says: demand at least one more replica.  The default
       (16) sits above the in-system depth an SLO-shedding admission
       policy steadily allows, so the pressure valve only fires on
       genuinely runaway queues.
    4. *Interference* — while the fleet is at (or beyond) its demand,
       every active replica whose detector currently reports
       interference (and whose signal is fresh, i.e. it served within
       ``freshness_window`` fleet arrivals) adds one to the demand:
       scale out around degraded capacity instead of routing into it.
       When over-provisioned the bump is skipped — the membership
       ranking below drains the interfered replica instead.

    The demand is clamped to ``[min_active, num_replicas]`` and the
    membership is chosen deterministically — currently-active,
    non-interfered replicas first (stability), then clean inactive
    ones (scale-out targets), then interfered ones last (drain
    preference) — so drained replicas simply stop receiving new work
    and finish what they have.

    Closed-loop runs have no exogenous arrival clock; the measured
    "offered" rate then equals the fleet's own service rate, so the
    autoscaler converges on keeping every replica active (i.e. it
    degenerates to ``static``, which tests pin).
    """

    def __init__(
        self,
        window: int = 64,
        interval: int = 16,
        target_util: float = 0.75,
        min_active: int = 1,
        backlog_per_replica: float = 16.0,
        freshness_window: int = 8,
    ):
        if window < 2:
            raise ValueError(f"window must be >= 2, got {window}")
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval}")
        if not 0.0 < target_util <= 1.0:
            raise ValueError(f"target_util must be in (0, 1], got {target_util}")
        if min_active < 1:
            raise ValueError(f"min_active must be >= 1, got {min_active}")
        self.window = int(window)
        self.interval = int(interval)
        self.target_util = float(target_util)
        self.min_active = int(min_active)
        self.backlog_per_replica = float(backlog_per_replica)
        self.freshness_window = int(freshness_window)
        self._arrivals: deque = deque(maxlen=self.window)
        self._active: Optional[List[int]] = None
        self._since_update = 0

    def active(self, q: int, now: float, views) -> Sequence[int]:
        n = len(views)
        if self._active is None:
            self._active = list(range(n))
        self._arrivals.append(now)
        self._since_update += 1
        if self._since_update < self.interval:
            return self._active
        self._since_update = 0

        demand = self._demand(views)
        if demand is None:
            return self._active
        demand = max(self.min_active, min(demand, n))
        active_set = set(self._active)

        def rank(v):
            interfered = (
                v.since_assign <= self.freshness_window
                and v.interference_active
            )
            return (interfered, v.index not in active_set, v.index)

        chosen = sorted(sorted(views, key=rank)[:demand], key=lambda v: v.index)
        self._active = [v.index for v in chosen]
        return self._active

    def _demand(self, views) -> Optional[int]:
        """Replicas the current load profile needs; None = no signal."""
        if len(self._arrivals) < 2:
            return None
        span = self._arrivals[-1] - self._arrivals[0]
        if span <= 0.0:
            return None
        rate = (len(self._arrivals) - 1) / span
        beats = sorted(
            v.est_bottleneck
            for v in views
            if math.isfinite(v.est_bottleneck) and v.est_bottleneck > 0
        )
        if not beats:
            return None
        beat = beats[len(beats) // 2]
        demand = math.ceil(rate * beat / self.target_util)

        active_views = [v for v in views if v.index in set(self._active)]
        backlog = sum(v.outstanding for v in active_views)
        if backlog > self.backlog_per_replica * len(active_views):
            demand = max(demand, len(active_views) + 1)
        # Scale *out* around interfered capacity only while the load
        # actually needs it; when over-provisioned the right move is
        # draining the interfered replica (the membership ranking
        # already prefers that), not keeping spares active.
        if demand >= len(active_views):
            demand += sum(
                1
                for v in active_views
                if v.since_assign <= self.freshness_window and v.interference_active
            )
        return demand

    def reset(self) -> None:
        self._arrivals.clear()
        self._active = None
        self._since_update = 0
