"""String-keyed registries for admission policies and autoscalers.

Mirrors ``repro.schedulers`` / ``repro.workloads`` / ``repro.cluster``:
implementations register under a name, callers construct by name with
one superset of keyword arguments filtered against each class's
``__init__`` (``cap`` means nothing to ``slo_shed``).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Type, Union

from repro.util.registry import Registry

# Importing the builtins modules runs their @register_* decorators;
# lazy so registry.py itself stays import-cycle-free.
_ADMISSION = Registry("admission policy", builtins_module="repro.control.policies")
_AUTOSCALER = Registry("autoscaler", builtins_module="repro.control.autoscalers")


def register_admission(name: str, **defaults) -> Callable[[Type], Type]:
    """Class decorator registering an AdmissionPolicy under ``name``."""
    return _ADMISSION.register(name, **defaults)


def unregister_admission(name: str) -> None:
    """Remove a registration (tests / plugin reload)."""
    _ADMISSION.unregister(name)


def available_admission_policies() -> List[str]:
    """Sorted names of every registered admission policy."""
    return _ADMISSION.available()


def admission_class(name: str) -> Type:
    return _ADMISSION.cls(name)


def make_admission(name: str, **kwargs):
    """Construct the admission policy registered under ``name``."""
    return _ADMISSION.make(name, **kwargs)


def resolve_admission(
    admission: Union[str, object, None], admission_kwargs: Optional[dict] = None
):
    """Name (+ kwargs) or instance -> AdmissionPolicy instance.

    ``None`` resolves to ``None`` (control plane disabled) — distinct
    from the registered ``"none"`` policy only in that no policy object
    is threaded through the run loop at all.
    """
    if admission is None:
        if admission_kwargs:
            raise ValueError("admission_kwargs given but no admission policy selected")
        return None
    if isinstance(admission, str):
        return make_admission(admission, **(admission_kwargs or {}))
    if admission_kwargs:
        raise ValueError(
            "admission_kwargs only apply to an admission-policy name, "
            "not an already-constructed instance"
        )
    return admission


def register_autoscaler(name: str, **defaults) -> Callable[[Type], Type]:
    """Class decorator registering an Autoscaler under ``name``."""
    return _AUTOSCALER.register(name, **defaults)


def unregister_autoscaler(name: str) -> None:
    """Remove a registration (tests / plugin reload)."""
    _AUTOSCALER.unregister(name)


def available_autoscalers() -> List[str]:
    """Sorted names of every registered autoscaler."""
    return _AUTOSCALER.available()


def autoscaler_class(name: str) -> Type:
    return _AUTOSCALER.cls(name)


def make_autoscaler(name: str, **kwargs):
    """Construct the autoscaler registered under ``name``."""
    return _AUTOSCALER.make(name, **kwargs)


def resolve_autoscaler(
    autoscaler: Union[str, object, None], autoscaler_kwargs: Optional[dict] = None
):
    """Name (+ kwargs) or instance -> Autoscaler instance."""
    if autoscaler is None:
        autoscaler = "static"
    if isinstance(autoscaler, str):
        return make_autoscaler(autoscaler, **(autoscaler_kwargs or {}))
    if autoscaler_kwargs:
        raise ValueError(
            "autoscaler_kwargs only apply to an autoscaler name, "
            "not an already-constructed instance"
        )
    return autoscaler
