"""SLO control plane: admission control, load shedding, autoscaling.

The fifth pluggable axis (after schedulers, workloads, batching and
routing): an :class:`AdmissionPolicy` decides per arrival whether a
query enters the pipeline at all (``none`` / ``queue_cap`` /
``slo_shed`` / ``adaptive_batch``), and an :class:`Autoscaler` decides
per fleet arrival which cluster replicas are active (``static`` /
``load_profile``).  Both thread through the one run loop — simulator,
live engine and cluster report the identical shed/goodput surface.
See docs/CONTROL.md.
"""
from repro.control.base import (  # noqa: F401
    AdmissionPolicy,
    AdmissionView,
    Autoscaler,
)
from repro.control.autoscalers import (  # noqa: F401
    LoadProfileAutoscaler,
    StaticAutoscaler,
)
from repro.control.policies import (  # noqa: F401
    AdaptiveBatchAdmission,
    AdmitAll,
    QueueCapAdmission,
    SloShedAdmission,
)
from repro.control.registry import (  # noqa: F401
    admission_class,
    autoscaler_class,
    available_admission_policies,
    available_autoscalers,
    make_admission,
    make_autoscaler,
    register_admission,
    register_autoscaler,
    resolve_admission,
    resolve_autoscaler,
    unregister_admission,
    unregister_autoscaler,
)
