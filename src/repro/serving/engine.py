"""Live serving engine: scheduler policies against *measured* stage times.

This is the end-to-end integration of the paper's technique: real JAX
model execution through the recompile-free pipeline executor, per-stage
wall-clock monitoring, online interference detection, and stepwise
rebalancing — one exploration trial per (serially processed) query.

The detect → explore → commit state machine is the same
:class:`~repro.schedulers.runtime.RebalanceRuntime` the simulator
drives, and the per-query loop itself is the same
:func:`repro.workloads.run_pipeline`: the engine only supplies physical
time (a :class:`~repro.pipeline.executor.MeasuredTimeSource` built from
the EMA of measured per-block times) where the simulator supplies
database lookups.  Any registered policy name — or a custom
:class:`~repro.schedulers.base.SchedulerPolicy` instance — plugs in, as
does any registered workload (closed-loop by default; ``poisson`` /
``bursty`` / ``trace`` for open-loop runs with queueing accounting in
wall-clock seconds).

Detection runs at the shared
:data:`repro.schedulers.DEFAULT_REL_THRESHOLD` in the detector's
EMA/hysteresis mode (measured times jitter query-to-query; see
``repro.schedulers.defaults``).

Interference is injected as per-EP slowdown factors (emulating co-located
tenants; the measured-database builder in tools/ uses real co-running
stressor processes instead).

``serve(..., max_batch=N)`` enables batched serving: open-loop arrivals
that queued up behind the pipeline are stacked and executed through
``LocalPipelineExecutor.run_batch`` — one set of stage dispatches per
burst — while the detect → explore → commit machinery still observes
every query (docs/WORKLOADS.md "Batching & the fast path").

``serve(..., batching="continuous", buckets=...)`` enables continuous
batching on top: length-bucketed formed dispatches run stage by stage
through the executor's stage-granular ``run_stages``, and a query that
arrives while a same-bucket batch is in flight joins it at the next
pipeline-stage boundary — one fused catch-up launch instead of waiting
out the full group-synchronous drain (docs/WORKLOADS.md "Continuous
batching & length buckets").
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.mesh import (
    balanced_assignment,
    collective_frac as _mesh_collective_frac,
    mesh_stage_times,
    resolve_mesh,
)
from repro.core.pipeline_state import balanced_config, throughput
from repro.pipeline.executor import (
    LocalPipelineExecutor,
    MeasuredTimeSource,
    next_pow2,
)
from repro.schedulers.base import SchedulerPolicy
from repro.schedulers.defaults import DEFAULT_ALPHA, MEASURED_DETECTOR_MODE
from repro.schedulers.registry import make_scheduler
from repro.util.errors import QueryError
from repro.schedulers.runtime import RebalanceRuntime, RuntimeStep
from repro.workloads import (
    BatchRecord,
    DispatchRecord,
    PipelineTrace,
    QueryRecord,
    Workload,
    resolve_batching,
)
from repro.workloads.runner import _run_pipeline_impl

#: Deprecated alias — ``serve()`` now returns the unified
#: :class:`repro.workloads.PipelineTrace` (same summary keys plus the
#: queueing/SLO surface the simulator already had).
ServeMetrics = PipelineTrace


class _LiveQueryExecutor:
    """Engine-side :class:`~repro.workloads.QueryExecutor`.

    Each query runs for real through the
    :class:`~repro.pipeline.executor.LocalPipelineExecutor`; the
    scheduler runtime is polled with a
    :class:`~repro.pipeline.executor.MeasuredTimeSource` over the
    engine's online per-block time estimates.  Until the first query has
    been measured there are no estimates to reason over, so
    ``begin_query`` returns ``None`` and the query runs steady.

    With ``max_batch > 1`` the executor opts into the run loop's real
    batching (``batch_mode = "batch"``): queries that have already
    arrived are drained into one stacked
    :meth:`~repro.pipeline.executor.LocalPipelineExecutor.run_batch`
    call — one set of stage dispatches + device syncs per burst instead
    of one per query.  The scheduler is still polled per query (the
    EMA/hysteresis detector must see every observation), so
    rebalance/trial accounting stays aligned with the unbatched run.
    """

    def __init__(self, engine: "ServingEngine",
                 queries: Sequence[jnp.ndarray], slowdown_schedule,
                 max_batch: int = 1):
        self.engine = engine
        self.queries = queries
        self.schedule = slowdown_schedule
        self.max_batch = max(1, int(max_batch))
        self._slow: Optional[np.ndarray] = None
        self._cf = 1.0  # live collective-contention factor (mesh runs)
        # Batched-dispatch state (the run loop's configure_batching
        # hook fills these in when a BatchFormer is attached).
        self.former = None
        self._lengths: Optional[np.ndarray] = None
        self._padded: Optional[np.ndarray] = None

    @property
    def batch_mode(self) -> Optional[str]:
        return "batch" if self.max_batch > 1 else None

    @property
    def max_chunk(self) -> int:
        return self.max_batch

    def begin_query(self, q: int) -> Optional[MeasuredTimeSource]:
        self._slow = np.asarray(self.schedule(q), float)
        self._cf = self.engine._coll_factor_at(q)
        if self.engine._block_times is None:
            return None
        return self.engine._measured_source(self._slow, self._cf)

    def steady_horizon(self, q: int) -> int:
        """Constant-interference run length from ``q``: a batch must
        share one slowdown vector (a schedule edge ends the chunk), one
        collective-contention factor when a mesh is armed, and one
        dispatch shape (stacked rows need one shared sequence
        length — a length change ends the chunk; with buckets attached
        the cut falls at bucket-edge changes instead)."""
        base = np.asarray(self.schedule(q), float)
        cf = self.engine._coll_factor_at(q)
        width = self._width(q)
        n = 1
        while (n < self.max_batch and q + n < len(self.queries)
               and self._width(q + n) == width
               and self.engine._coll_factor_at(q + n) == cf
               and np.array_equal(np.asarray(self.schedule(q + n), float),
                                  base)):
            n += 1
        return n

    def _width(self, q: int) -> int:
        """Sequence length query ``q`` dispatches at (bucket edge when
        buckets are attached, raw length otherwise)."""
        if self._padded is not None:
            return int(self._padded[q])
        return int(self.queries[q].shape[-1])

    # -- batched dispatch (run-loop hooks) --------------------------------

    def configure_batching(self, former, lengths, padded) -> None:
        """Run-loop hook (:func:`repro.workloads.run_pipeline`): attach
        the dispatch former and the per-query (raw, padded) lengths.

        Pre-compiles the closed bucketed shape set — power-of-two row
        counts x the bucket edges traffic actually uses — so no
        dispatch inside the serving loop ever pays (or measures) a
        first-shape XLA compile, and the executor's ``_warmed`` set
        stays bounded however many raw shapes the traffic offers.
        """
        self.former = former
        self._lengths = (None if lengths is None
                         else np.asarray(lengths, dtype=np.int64))
        self._padded = (None if padded is None
                        else np.asarray(padded, dtype=np.int64))
        if former is None:
            return
        self.max_batch = max(self.max_batch, int(former.max_batch))
        if self._padded is not None:
            edges = sorted({int(s) for s in self._padded})
        else:
            edges = sorted({int(t.shape[-1]) for t in self.queries})
        max_rows = max((int(t.shape[0]) for t in self.queries), default=1)
        self.engine.executor.warm_buckets(edges, self.max_batch * max_rows)

    def _dispatch_tokens(self, q: int) -> jnp.ndarray:
        """Query ``q``'s tokens, zero-padded along the sequence axis to
        its bucket edge so every dispatch shape comes from the closed
        warm set."""
        t = self.queries[q]
        if self._padded is None:
            return t
        seq = int(self._padded[q])
        raw = int(t.shape[-1])
        if seq == raw:
            return t
        return jnp.pad(t, ((0, 0), (0, seq - raw)))

    def begin_dispatch(self, q0: int,
                       step: RuntimeStep) -> "_LiveDispatchBuilder":
        return _LiveDispatchBuilder(self, q0, step)

    def _measure(self, config, first_measurement: bool):
        """Post-execution bookkeeping shared by both paths: bottleneck
        time, EMA estimate refresh, first-measurement detector arming."""
        eng = self.engine

        def finish(stage_times_per_query: np.ndarray) -> float:
            live = [i for i, c in enumerate(config) if c > 0]
            tmax = float(stage_times_per_query[live].max())
            eng._update_block_estimates(config, stage_times_per_query,
                                        self._slow)
            if first_measurement:
                # Arm detection against this query's measured
                # conditions, so interference beginning at the very
                # next query is a shift from this baseline rather
                # than the baseline.
                eng.runtime.arm(
                    eng._measured_source(self._slow, self._cf))
            return tmax

        return finish

    def _mesh_model(self, stage_times: np.ndarray, config,
                    assignment) -> tuple:
        """Scheduler-side sharded-stage model over *measured* per-stage
        compute times: (modeled bottleneck time, collective share).
        Wall-clock service latencies are never rewritten — only the
        capability/throughput signal the admission ledger and the trace
        columns consume (docs/SHARDING.md)."""
        eng = self.engine
        mt = mesh_stage_times(stage_times, config, assignment, eng.mesh,
                              self._cf, layer_costs=eng._coll_times)
        live = [i for i, c in enumerate(config) if c > 0]
        tmax = float(np.asarray(mt)[live].max())
        cf = _mesh_collective_frac(stage_times, config, assignment,
                                   eng.mesh, self._cf,
                                   layer_costs=eng._coll_times)
        return max(tmax, 1e-12), cf

    def execute(self, q: int, step: RuntimeStep) -> QueryRecord:
        eng = self.engine
        if self.former is not None:
            tokens = self._dispatch_tokens(q)
            rows = int(tokens.shape[0])
            pr = next_pow2(rows)
            eng.executor.ensure_warm(pr, int(tokens.shape[-1]))
            if pr > rows:
                tokens = jnp.concatenate(
                    [tokens, jnp.zeros((pr - rows, tokens.shape[-1]),
                                       tokens.dtype)])
        else:
            tokens = self.queries[q]
        finish = self._measure(step.config, eng._block_times is None)
        t0 = time.perf_counter()
        _, st = eng.executor.run_query(tokens, step.config,
                                       slowdowns=self._slow)
        latency = time.perf_counter() - t0
        tmax = finish(st)
        coll_frac = 0.0
        if eng.mesh is not None and step.mesh is not None:
            tmax, coll_frac = self._mesh_model(st, step.config, step.mesh)
        if self.former is not None:
            # Batched dispatch is group-synchronous — a solo dispatch
            # holds the pipeline for its full drain, exactly like a
            # singleton formed batch.
            return QueryRecord(service_latency=latency,
                               throughput=1.0 / max(latency, 1e-12),
                               collective_frac=coll_frac)
        return QueryRecord(service_latency=latency,
                           throughput=1.0 / max(tmax, 1e-12),
                           collective_frac=coll_frac)

    def execute_many(self, q0: int, steps) -> BatchRecord:
        eng = self.engine
        n = len(steps)
        batch = [self.queries[q0 + i] for i in range(n)]
        # Never measure a first-shape XLA compile as service time.  The
        # key set here is bounded by construction (row sums never exceed
        # max_batch, one seq per chunk); the formed-dispatch paths use
        # the power-of-two warm family instead, since joins grow rows
        # dynamically.
        eng.executor.ensure_warm(sum(int(t.shape[0]) for t in batch),
                                 int(batch[0].shape[-1]))
        finish = self._measure(steps[0].config, eng._block_times is None)
        t0 = time.perf_counter()
        _, st = eng.executor.run_batch(batch, steps[0].config,
                                       slowdowns=self._slow)
        wall = time.perf_counter() - t0
        # Stage times cover the whole batch; the per-query estimate the
        # EMA consumes is the per-query share.
        tmax = max(finish(st / n), 1e-12)
        coll_fracs = None
        if eng.mesh is not None and steps[0].mesh is not None:
            tmax, cf = self._mesh_model(st / n, steps[0].config,
                                        steps[0].mesh)
            coll_fracs = np.broadcast_to(cf, n)
        # The batch holds the admission head for one batch-bottleneck
        # beat (per-query occupancy = tmax_batch / n) and every member
        # completes when the batch drains.  The run loop staggers member
        # starts by exactly that occupancy (members are queued by
        # construction), so attributing service = wall - i * occupancy
        # lands every completion at dispatch + wall — the stagger is
        # head-of-line accounting, not extra service.
        return BatchRecord(
            service_latencies=wall - np.arange(n) * tmax,
            throughputs=np.broadcast_to(1.0 / tmax, n),
            collective_fracs=coll_fracs)


class _LiveDispatchBuilder:
    """One physical batched dispatch, executed stage by stage.

    The live counterpart of the simulator's dispatch builder: formation
    members are stacked (sequence-padded to the bucket edge, rows
    rounded up to a warm power of two) and embedded once, then the run
    loop drives the pipeline one stage at a time through the executor's
    stage-granular ``run_stages``.  At each stage boundary a newly
    arrived same-bucket query can :meth:`join`: it pays one fused
    catch-up launch (embed + stages ``[0, s)`` over the joiner alone),
    then its rows are spliced into the in-flight batch, which resumes
    wider — no drain, no recompile (stage bounds and the batch dimension
    are runtime arguments).

    All times are wall-clock offsets from the dispatch launch.  Batched
    dispatch is group-synchronous — the next dispatch launches only
    after this one drains — so the record's throughput is ``1 / drain``.
    Every compiled shape this builder touches comes from the closed
    bucketed warm set (``configure_batching`` pre-compiled it); the
    ``ensure_warm`` calls before each timed window are bounded-set
    lookups, never compiles.
    """

    def __init__(self, live: "_LiveQueryExecutor", q0: int,
                 step: RuntimeStep):
        self._live = live
        eng = live.engine
        self._ex = eng.executor
        self._config = list(step.config)
        self._mesh = (list(step.mesh) if step.mesh is not None else None)
        self._S = len(self._config)
        self._bounds = self._ex._device_bounds(self._config)
        self._slow = live._slow
        self._first = eng._block_times is None
        self._seq = live._width(q0)
        self._members: List[int] = []
        self._starts: List[float] = []
        self._stage = 0
        self._launched = False
        self._t0 = 0.0
        self._x = None
        self._positions = None
        self._rows = 0       # real (non-padding) rows in self._x
        self._stage_times = np.zeros(self._S)
        self._stage_members = np.zeros(self._S)
        self._actual_tok = 0.0

    def add(self, q: int) -> None:
        """Formation member: present from stage 0 (start offset 0)."""
        self._members.append(q)
        self._starts.append(0.0)
        self._count_tokens(q)

    def _count_tokens(self, q: int) -> None:
        live = self._live
        rows = int(live.queries[q].shape[0])
        raw = (int(live._lengths[q]) if live._lengths is not None
               else int(live.queries[q].shape[-1]))
        self._actual_tok += float(rows) * float(raw)

    def _pad_rows(self, arr: jnp.ndarray, rows: int) -> jnp.ndarray:
        pr = next_pow2(rows)
        if pr > rows:
            arr = jnp.concatenate(
                [arr, jnp.zeros((pr - rows,) + arr.shape[1:], arr.dtype)])
        return arr

    def _launch(self) -> None:
        toks = [self._live._dispatch_tokens(q) for q in self._members]
        tokens = toks[0] if len(toks) == 1 else jnp.concatenate(toks)
        rows = int(tokens.shape[0])
        self._ex.ensure_warm(next_pow2(rows), self._seq)
        tokens = self._pad_rows(tokens, rows)
        self._rows = rows
        self._launched = True
        self._t0 = time.perf_counter()
        self._x, self._positions = self._ex.embed_tokens(tokens)

    def _run_stage(self) -> None:
        s = self._stage
        self._stage_members[s] = len(self._members)
        self._x, st = self._ex.run_stages(
            self._x, self._positions, self._config, s, s + 1,
            slowdowns=self._slow, bounds=self._bounds)
        self._stage_times[s] = float(st[0])
        self._stage += 1

    def next_boundary(self) -> Optional[float]:
        """Run the next stage; return the boundary's wall-clock offset
        (a join opportunity) or ``None`` after the final stage."""
        if not self._launched:
            self._launch()
        self._run_stage()
        if self._stage >= self._S:
            return None
        return time.perf_counter() - self._t0

    def join(self, q: int) -> None:
        if not 0 < self._stage < self._S:
            raise QueryError("join() is only valid at a stage boundary")
        live, ex = self._live, self._ex
        tokens = live._dispatch_tokens(q)
        jrows = int(tokens.shape[0])
        new_rows = self._rows + jrows
        # Both shapes the timed window touches, checked warm up front.
        ex.ensure_warm(next_pow2(jrows), self._seq)
        ex.ensure_warm(next_pow2(new_rows), self._seq)
        tokens = self._pad_rows(tokens, jrows)
        self._starts.append(time.perf_counter() - self._t0)
        # One fused catch-up launch: embed, then every block of stages
        # [0, s) in a single ``stage_fn`` dispatch — block bounds are
        # runtime arguments, so the catch-up pays one dispatch + one
        # device sync however many stages the batch already ran (the
        # per-stage loop would price a join like a near-full solo
        # query).  Then splice the joiner's real rows into the
        # in-flight batch and re-pad to the next warm row count.
        h, positions = ex.embed_tokens(tokens)
        t1 = time.perf_counter()
        h = ex._stage_fn(ex.params, h, positions,
                         self._bounds[0][0],
                         self._bounds[self._stage - 1][1])
        h.block_until_ready()
        if self._slow is not None:
            # Interference emulation for the fused span: stretch by the
            # mean slowdown of the stages it covers (run_stages does
            # this per stage; the fused launch can't attribute within).
            stretch = float(np.mean(
                np.asarray(self._slow, float)[:self._stage]))
            if stretch > 1.0:
                time.sleep((time.perf_counter() - t1) * (stretch - 1.0))
        x = jnp.concatenate([self._x[:self._rows], h[:jrows]])
        x = self._pad_rows(x, new_rows)
        x.block_until_ready()
        self._x = x
        self._positions = jnp.broadcast_to(
            jnp.arange(self._seq, dtype=jnp.int32),
            (int(x.shape[0]), self._seq))
        self._rows = new_rows
        self._members.append(q)
        self._count_tokens(q)

    def finish(self) -> DispatchRecord:
        if not self._launched:
            self._launch()
        while self._stage < self._S:
            self._run_stage()
        self._ex.head(self._x)
        drain = time.perf_counter() - self._t0
        # Per-query stage-time attribution for the EMA: each stage's
        # measured time is shared by the members present when it ran
        # (joiners' catch-up work is dispatch latency, not a per-block
        # time signal).
        done = self._live._measure(self._config, self._first)
        per_query = self._stage_times / np.maximum(self._stage_members, 1.0)
        done(per_query)
        coll_frac = 0.0
        if self._live.engine.mesh is not None and self._mesh is not None:
            _, coll_frac = self._live._mesh_model(per_query, self._config,
                                                  self._mesh)
        return DispatchRecord(
            start_offsets=np.asarray(self._starts, float),
            drain=drain,
            throughput=1.0 / max(drain, 1e-12),
            padded_tokens=float(next_pow2(self._rows)) * float(self._seq),
            actual_tokens=self._actual_tok,
            collective_frac=coll_frac)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, num_eps: int,
                 scheduler: Union[str, SchedulerPolicy] = "odin",
                 alpha: int = DEFAULT_ALPHA,
                 rel_threshold: Optional[float] = None,
                 estimate_beta: float = 0.5,
                 executor: Optional[LocalPipelineExecutor] = None,
                 mesh=None,
                 coll_factor_schedule=None):
        self.cfg = cfg
        # Mesh-sliced stages (docs/SHARDING.md): scheduler-side modeling
        # over measured compute times.  ``mesh`` accepts anything
        # :func:`repro.core.mesh.resolve_mesh` takes (the RunSpec path
        # is the intended entry — docs/API.md); ``coll_factor_schedule
        # (q) -> float`` emulates collective contention the way
        # ``slowdown_schedule`` emulates compute interference.  Unset
        # (the default), every mesh code path is dormant and serving is
        # bit-identical to a pre-mesh build.
        self.mesh = resolve_mesh(mesh)
        self.coll_factor_schedule = coll_factor_schedule
        self._coll_times = (self.mesh.layer_costs(cfg.num_blocks)
                            if self.mesh is not None else None)
        self._initial_assignment = (
            balanced_assignment(self.mesh.devices, num_eps)
            if self.mesh is not None else None)
        # ``executor`` lets N engines share one jitted pipeline (the
        # multi-replica cluster pattern: replicas serve the same model,
        # so one compile + warmup serves the fleet, while every engine
        # keeps its own runtime/detector/estimate state).
        self.executor = (executor if executor is not None
                         else LocalPipelineExecutor(cfg, params))
        self.num_eps = num_eps
        # Weight of the newest measurement in the per-block clean-time
        # EMA.  0.5 (default) tracks fast; smaller values smooth
        # measurement jitter out of the estimates the explorer compares,
        # making exploration walks reproducible on noisy hosts.
        self.estimate_beta = float(estimate_beta)
        if isinstance(scheduler, str):
            self.policy = make_scheduler(scheduler, alpha=alpha,
                                         rel_threshold=rel_threshold,
                                         detector=MEASURED_DETECTOR_MODE)
            self.scheduler = scheduler
        else:
            self.policy = scheduler
            self.scheduler = getattr(scheduler, "name",
                                     type(scheduler).__name__)
        self._initial_config = balanced_config(cfg.num_blocks, num_eps)
        self.runtime = RebalanceRuntime(self.policy, self._initial_config,
                                        mesh=self._initial_assignment)
        # EMA of measured per-block times feeds the scheduler's trial
        # evaluations between real executions.
        self._block_times: Optional[np.ndarray] = None

    def _coll_factor_at(self, q: int) -> float:
        """Collective-contention factor for query ``q`` (1.0 quiet /
        unsharded)."""
        if self.mesh is None or self.coll_factor_schedule is None:
            return 1.0
        return float(self.coll_factor_schedule(q))

    def _measured_source(self, slowdowns,
                         coll_factor: float = 1.0) -> MeasuredTimeSource:
        """The scheduler's time source over the current block-time
        estimates — mesh-aware when a mesh is armed (the runtime syncs
        the committed assignment on every poll)."""
        if self.mesh is None:
            return MeasuredTimeSource(self._block_times, slowdowns)
        return MeasuredTimeSource(self._block_times, slowdowns,
                                  mesh=self.mesh,
                                  coll_times=self._coll_times,
                                  assignment=self.runtime.mesh,
                                  coll_factor=coll_factor)

    @property
    def config(self) -> List[int]:
        """Current committed stage configuration."""
        return list(self.runtime.config)

    def reset_policy(self) -> None:
        """Fresh serving window: abandon any in-flight phase, re-arm
        detection, and restart from the balanced initial configuration.
        Online block-time estimates are kept (they describe the model,
        not the window) — combined with ``estimate_beta = 0`` this makes
        scheduling decisions reproducible across serving windows, e.g.
        for A/B comparisons of ``serve(..., max_batch=...)``."""
        self.runtime.reset(self._initial_config,
                           mesh=self._initial_assignment)

    def estimated_peak_throughput(self) -> float:
        """Interference-free throughput of the starting configuration,
        from the online clean per-block estimates — the live analogue of
        the simulator's "executing alone" peak reference.  NaN until a
        query has been measured."""
        if self._block_times is None:
            return float("nan")
        clean = MeasuredTimeSource(self._block_times,
                                   np.ones(self.num_eps),
                                   mesh=self.mesh,
                                   coll_times=self._coll_times,
                                   assignment=self._initial_assignment)
        return throughput(clean.stage_times(self._initial_config))

    def _update_block_estimates(self, config: Sequence[int],
                                stage_times: np.ndarray,
                                slowdowns: Sequence[float]) -> None:
        """Refresh per-block clean-time estimates from a measured query.

        Vectorized: one ``np.repeat`` spreads each stage's de-slowed
        per-block time over its blocks (empty stages repeat zero times
        and contribute nothing), one fused EMA update runs in place.
        The first measurement seeds the estimates directly — averaging
        against a placeholder would hand the detector a reference that
        drifts for the next ~1/beta queries.
        """
        counts = np.asarray(config, dtype=np.int64)
        per_stage = (np.asarray(stage_times, float)
                     / np.maximum(np.asarray(slowdowns, float), 1e-9)
                     / np.maximum(counts, 1))
        per_block = np.repeat(per_stage, counts)
        if self._block_times is None:
            self._block_times = per_block.copy()
            return
        b = self.estimate_beta
        self._block_times[:] = (1.0 - b) * self._block_times + b * per_block

    def query_executor(self, queries: Sequence[jnp.ndarray],
                       slowdown_schedule,
                       max_batch: int = 1) -> "_LiveQueryExecutor":
        """This engine's :class:`~repro.workloads.QueryExecutor` half,
        for external drivers (``repro.cluster`` builds one per replica
        and feeds it through the shared run loop).  ``queries`` may be
        a *growing* sequence: the cluster appends each routed query
        before it executes."""
        return _LiveQueryExecutor(self, queries, slowdown_schedule,
                                  max_batch=max_batch)

    def _serve_impl(self, queries: Sequence[jnp.ndarray],
              slowdown_schedule,
              workload: Union[str, Workload, None] = "closed",
              workload_kwargs: Optional[dict] = None,
              max_batch: int = 1,
              batching: Union[str, object, None] = None,
              buckets: Union[str, object, None] = None,
              explore_in_batch: bool = False,
              admission: Union[str, object, None] = None,
              admission_kwargs: Optional[dict] = None,
              trace_mode: str = "dense",
              metrics_sink=None,
              sink_interval: Optional[int] = None,
              faults=None,
              retries=None,
              tiers=None,
              tiers_kwargs: Optional[dict] = None) -> PipelineTrace:
        """Serve ``queries`` under ``slowdown_schedule(q) -> per-EP
        slowdown factors (>= 1.0)``.

        ``workload`` picks the arrival process (``repro.workloads``):
        the default closed loop executes back-to-back exactly as before;
        open-loop workloads (rates in queries/second of wall-clock
        service time) additionally report queueing delay and offered
        vs. achieved load in the returned trace.

        ``max_batch > 1`` turns on batched serving (docs/WORKLOADS.md
        "Batching & the fast path"): queued arrivals are stacked and
        executed together, up to ``max_batch`` per dispatch, so bursts
        amortize stage dispatch + sync overhead instead of queueing
        one-by-one.  Batches never span an interference edge or a
        rebalance, and only queries that have already arrived join
        (a closed loop therefore still serves one at a time).

        ``batching`` selects the formed-dispatch path instead
        (docs/WORKLOADS.md "Continuous batching & length buckets"):
        ``"drain"`` forms length-bucketed batches that run to
        completion; ``"continuous"`` additionally admits arrivals into
        the in-flight batch at pipeline-stage boundaries via the
        executor's stage-granular ``run_stages`` — a joiner pays one
        fused catch-up launch instead of waiting out the full
        group-synchronous drain.  ``buckets`` picks the length buckets
        (``"pow2:lo:hi"``, an edge list, or ``None`` for raw lengths);
        queries are sequence-padded to their bucket edge and batches
        row-padded to powers of two, so every dispatch shape comes from
        a small pre-compiled set.  ``explore_in_batch`` lets an
        exploration trial ride at the head of a formed batch instead of
        forcing serial one-at-a-time processing.  With ``batching``
        set, ``max_batch`` caps the formed dispatch width.

        ``admission`` selects a :mod:`repro.control` admission policy
        (e.g. ``admission="slo_shed", admission_kwargs={"slo":
        0.25}`` — SLO in wall-clock seconds); shed queries are turned
        away before touching the executor and reported through the
        trace's shed/goodput surface (docs/CONTROL.md).

        ``trace_mode="streaming"`` / ``metrics_sink`` select the
        flat-memory telemetry path (docs/TELEMETRY.md), identically to
        the simulator: streaming runs return a
        :class:`~repro.telemetry.StreamingTrace`, sinks receive
        periodic snapshots in either mode.

        ``faults`` / ``retries`` inject deterministic failures and arm
        the retry budget (docs/FAULTS.md) — the same surface as the
        simulator, realized by wrapping this engine's executor in a
        :class:`~repro.faults.FaultingExecutor`.  Both default off
        (fault-free serving is unchanged).

        ``tiers`` / ``tiers_kwargs`` stamp arrivals with QoS tiers
        (docs/QOS.md) exactly as in the simulator — the resolution and
        the per-arrival draws live in the shared run loop, so a sim
        and a live run of the same seed see identical tier plans.
        """
        seq_max = max((int(t.shape[-1]) for t in queries), default=1)
        former = resolve_batching(batching, max_batch=max_batch,
                                  buckets=buckets,
                                  explore_in_batch=explore_in_batch,
                                  seq=seq_max)
        lengths = None
        if former is not None:
            # Real query shapes are the length distribution here — the
            # generators in repro.workloads.lengths drive query
            # *construction* (launch CLI, examples), not serving.
            lengths = np.array([int(t.shape[-1]) for t in queries],
                               dtype=np.int64)
        live = self.query_executor(
            queries, slowdown_schedule,
            max_batch=(former.max_batch if former is not None
                       else max_batch))
        trace = _run_pipeline_impl(live, self.runtime, len(queries),
                             workload=workload,
                             workload_kwargs=workload_kwargs,
                             scheduler_name=self.scheduler,
                             admission=admission,
                             admission_kwargs=admission_kwargs,
                             trace_mode=trace_mode,
                             metrics_sink=metrics_sink,
                             sink_interval=sink_interval,
                             former=former,
                             lengths=lengths,
                             faults=faults, retries=retries,
                             tiers=tiers, tiers_kwargs=tiers_kwargs)
        # The peak reference only exists after measurement: stamp it
        # post-hoc so the trace's SLO metrics work like the simulator's.
        trace.peak_throughput = self.estimated_peak_throughput()
        return trace

    def serve(self, queries: Sequence[jnp.ndarray],
              slowdown_schedule,
              workload: Union[str, Workload, None] = "closed",
              workload_kwargs: Optional[dict] = None,
              max_batch: int = 1,
              batching: Union[str, object, None] = None,
              buckets: Union[str, object, None] = None,
              explore_in_batch: bool = False,
              admission: Union[str, object, None] = None,
              admission_kwargs: Optional[dict] = None,
              trace_mode: str = "dense",
              metrics_sink=None,
              sink_interval: Optional[int] = None,
              faults=None,
              retries=None,
              tiers=None,
              tiers_kwargs: Optional[dict] = None) -> PipelineTrace:
        """Serve ``queries`` under ``slowdown_schedule(q) -> per-EP
        slowdown factors``.

        Thin wrapper over the unified :class:`repro.api.RunSpec` path
        (one declaration, one dispatcher — docs/API.md); the kwargs
        here map 1:1 onto spec fields and new options land on the spec
        (or, for physical per-engine state like the device mesh, on
        the :class:`ServingEngine` constructor — docs/SHARDING.md)
        instead of this signature.  See :meth:`_serve_impl` for the
        full kwarg-level documentation.
        """
        from repro import api
        spec = api.RunSpec(
            engine=self, queries=queries, schedule=slowdown_schedule,
            workload=api.WorkloadSpec(name=workload,
                                      kwargs=workload_kwargs),
            admission=api.AdmissionSpec(name=admission,
                                        kwargs=admission_kwargs),
            batching=api.BatchingSpec(mode=batching, max_batch=max_batch,
                                      buckets=buckets,
                                      explore_in_batch=explore_in_batch),
            faults=api.FaultsSpec(plan=faults),
            retries=api.RetriesSpec(policy=retries),
            tiers=api.TiersSpec(spec=tiers, kwargs=tiers_kwargs),
            telemetry=api.TelemetrySpec(trace_mode=trace_mode,
                                        metrics_sink=metrics_sink,
                                        sink_interval=sink_interval))
        return api.run(spec)
