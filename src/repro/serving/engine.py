"""Live serving engine: scheduler policies against *measured* stage times.

This is the end-to-end integration of the paper's technique: real JAX
model execution through the recompile-free pipeline executor, per-stage
wall-clock monitoring, online interference detection, and stepwise
rebalancing — one exploration trial per (serially processed) query.

The detect → explore → commit state machine is the same
:class:`~repro.schedulers.runtime.RebalanceRuntime` the simulator
drives, and the per-query loop itself is the same
:func:`repro.workloads.run_pipeline`: the engine only supplies physical
time (a :class:`~repro.pipeline.executor.MeasuredTimeSource` built from
the EMA of measured per-block times) where the simulator supplies
database lookups.  Any registered policy name — or a custom
:class:`~repro.schedulers.base.SchedulerPolicy` instance — plugs in, as
does any registered workload (closed-loop by default; ``poisson`` /
``bursty`` / ``trace`` for open-loop runs with queueing accounting in
wall-clock seconds).

Detection runs at the shared
:data:`repro.schedulers.DEFAULT_REL_THRESHOLD` in the detector's
EMA/hysteresis mode (measured times jitter query-to-query; see
``repro.schedulers.defaults``).

Interference is injected as per-EP slowdown factors (emulating co-located
tenants; the measured-database builder in tools/ uses real co-running
stressor processes instead).

``serve(..., max_batch=N)`` enables batched serving: open-loop arrivals
that queued up behind the pipeline are stacked and executed through
``LocalPipelineExecutor.run_batch`` — one set of stage dispatches per
burst — while the detect → explore → commit machinery still observes
every query (docs/WORKLOADS.md "Batching & the fast path").
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pipeline_state import balanced_config, throughput
from repro.pipeline.executor import LocalPipelineExecutor, MeasuredTimeSource
from repro.schedulers.base import SchedulerPolicy
from repro.schedulers.defaults import DEFAULT_ALPHA, MEASURED_DETECTOR_MODE
from repro.schedulers.registry import make_scheduler
from repro.schedulers.runtime import RebalanceRuntime, RuntimeStep
from repro.workloads import (
    BatchRecord,
    PipelineTrace,
    QueryRecord,
    Workload,
    run_pipeline,
)

#: Deprecated alias — ``serve()`` now returns the unified
#: :class:`repro.workloads.PipelineTrace` (same summary keys plus the
#: queueing/SLO surface the simulator already had).
ServeMetrics = PipelineTrace


class _LiveQueryExecutor:
    """Engine-side :class:`~repro.workloads.QueryExecutor`.

    Each query runs for real through the
    :class:`~repro.pipeline.executor.LocalPipelineExecutor`; the
    scheduler runtime is polled with a
    :class:`~repro.pipeline.executor.MeasuredTimeSource` over the
    engine's online per-block time estimates.  Until the first query has
    been measured there are no estimates to reason over, so
    ``begin_query`` returns ``None`` and the query runs steady.

    With ``max_batch > 1`` the executor opts into the run loop's real
    batching (``batch_mode = "batch"``): queries that have already
    arrived are drained into one stacked
    :meth:`~repro.pipeline.executor.LocalPipelineExecutor.run_batch`
    call — one set of stage dispatches + device syncs per burst instead
    of one per query.  The scheduler is still polled per query (the
    EMA/hysteresis detector must see every observation), so
    rebalance/trial accounting stays aligned with the unbatched run.
    """

    def __init__(self, engine: "ServingEngine",
                 queries: Sequence[jnp.ndarray], slowdown_schedule,
                 max_batch: int = 1):
        self.engine = engine
        self.queries = queries
        self.schedule = slowdown_schedule
        self.max_batch = max(1, int(max_batch))
        self._slow: Optional[np.ndarray] = None

    @property
    def batch_mode(self) -> Optional[str]:
        return "batch" if self.max_batch > 1 else None

    @property
    def max_chunk(self) -> int:
        return self.max_batch

    def begin_query(self, q: int) -> Optional[MeasuredTimeSource]:
        self._slow = np.asarray(self.schedule(q), float)
        if self.engine._block_times is None:
            return None
        return MeasuredTimeSource(self.engine._block_times, self._slow)

    def steady_horizon(self, q: int) -> int:
        """Constant-interference run length from ``q``: a batch must
        share one slowdown vector (a schedule edge ends the chunk)."""
        base = np.asarray(self.schedule(q), float)
        n = 1
        while (n < self.max_batch and q + n < len(self.queries)
               and np.array_equal(np.asarray(self.schedule(q + n), float),
                                  base)):
            n += 1
        return n

    def _measure(self, config, first_measurement: bool):
        """Post-execution bookkeeping shared by both paths: bottleneck
        time, EMA estimate refresh, first-measurement detector arming."""
        eng = self.engine

        def finish(stage_times_per_query: np.ndarray) -> float:
            live = [i for i, c in enumerate(config) if c > 0]
            tmax = float(stage_times_per_query[live].max())
            eng._update_block_estimates(config, stage_times_per_query,
                                        self._slow)
            if first_measurement:
                # Arm detection against this query's measured
                # conditions, so interference beginning at the very
                # next query is a shift from this baseline rather
                # than the baseline.
                eng.runtime.arm(
                    MeasuredTimeSource(eng._block_times, self._slow))
            return tmax

        return finish

    def execute(self, q: int, step: RuntimeStep) -> QueryRecord:
        eng = self.engine
        finish = self._measure(step.config, eng._block_times is None)
        t0 = time.perf_counter()
        _, st = eng.executor.run_query(self.queries[q], step.config,
                                       slowdowns=self._slow)
        latency = time.perf_counter() - t0
        tmax = finish(st)
        return QueryRecord(service_latency=latency,
                           throughput=1.0 / max(tmax, 1e-12))

    def execute_many(self, q0: int, steps) -> BatchRecord:
        eng = self.engine
        n = len(steps)
        batch = [self.queries[q0 + i] for i in range(n)]
        # Never measure a first-shape XLA compile as service time.
        eng.executor.ensure_warm(sum(int(t.shape[0]) for t in batch),
                                 int(batch[0].shape[-1]))
        finish = self._measure(steps[0].config, eng._block_times is None)
        t0 = time.perf_counter()
        _, st = eng.executor.run_batch(batch, steps[0].config,
                                       slowdowns=self._slow)
        wall = time.perf_counter() - t0
        # Stage times cover the whole batch; the per-query estimate the
        # EMA consumes is the per-query share.
        tmax = max(finish(st / n), 1e-12)
        # The batch holds the admission head for one batch-bottleneck
        # beat (per-query occupancy = tmax_batch / n) and every member
        # completes when the batch drains.  The run loop staggers member
        # starts by exactly that occupancy (members are queued by
        # construction), so attributing service = wall - i * occupancy
        # lands every completion at dispatch + wall — the stagger is
        # head-of-line accounting, not extra service.
        return BatchRecord(
            service_latencies=wall - np.arange(n) * tmax,
            throughputs=np.broadcast_to(1.0 / tmax, n))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, num_eps: int,
                 scheduler: Union[str, SchedulerPolicy] = "odin",
                 alpha: int = DEFAULT_ALPHA,
                 rel_threshold: Optional[float] = None,
                 estimate_beta: float = 0.5,
                 executor: Optional[LocalPipelineExecutor] = None):
        self.cfg = cfg
        # ``executor`` lets N engines share one jitted pipeline (the
        # multi-replica cluster pattern: replicas serve the same model,
        # so one compile + warmup serves the fleet, while every engine
        # keeps its own runtime/detector/estimate state).
        self.executor = (executor if executor is not None
                         else LocalPipelineExecutor(cfg, params))
        self.num_eps = num_eps
        # Weight of the newest measurement in the per-block clean-time
        # EMA.  0.5 (default) tracks fast; smaller values smooth
        # measurement jitter out of the estimates the explorer compares,
        # making exploration walks reproducible on noisy hosts.
        self.estimate_beta = float(estimate_beta)
        if isinstance(scheduler, str):
            self.policy = make_scheduler(scheduler, alpha=alpha,
                                         rel_threshold=rel_threshold,
                                         detector=MEASURED_DETECTOR_MODE)
            self.scheduler = scheduler
        else:
            self.policy = scheduler
            self.scheduler = getattr(scheduler, "name",
                                     type(scheduler).__name__)
        self._initial_config = balanced_config(cfg.num_blocks, num_eps)
        self.runtime = RebalanceRuntime(self.policy, self._initial_config)
        # EMA of measured per-block times feeds the scheduler's trial
        # evaluations between real executions.
        self._block_times: Optional[np.ndarray] = None

    @property
    def config(self) -> List[int]:
        """Current committed stage configuration."""
        return list(self.runtime.config)

    def reset_policy(self) -> None:
        """Fresh serving window: abandon any in-flight phase, re-arm
        detection, and restart from the balanced initial configuration.
        Online block-time estimates are kept (they describe the model,
        not the window) — combined with ``estimate_beta = 0`` this makes
        scheduling decisions reproducible across serving windows, e.g.
        for A/B comparisons of ``serve(..., max_batch=...)``."""
        self.runtime.reset(self._initial_config)

    def estimated_peak_throughput(self) -> float:
        """Interference-free throughput of the starting configuration,
        from the online clean per-block estimates — the live analogue of
        the simulator's "executing alone" peak reference.  NaN until a
        query has been measured."""
        if self._block_times is None:
            return float("nan")
        clean = MeasuredTimeSource(self._block_times,
                                   np.ones(self.num_eps))
        return throughput(clean.stage_times(self._initial_config))

    def _update_block_estimates(self, config: Sequence[int],
                                stage_times: np.ndarray,
                                slowdowns: Sequence[float]) -> None:
        """Refresh per-block clean-time estimates from a measured query.

        Vectorized: one ``np.repeat`` spreads each stage's de-slowed
        per-block time over its blocks (empty stages repeat zero times
        and contribute nothing), one fused EMA update runs in place.
        The first measurement seeds the estimates directly — averaging
        against a placeholder would hand the detector a reference that
        drifts for the next ~1/beta queries.
        """
        counts = np.asarray(config, dtype=np.int64)
        per_stage = (np.asarray(stage_times, float)
                     / np.maximum(np.asarray(slowdowns, float), 1e-9)
                     / np.maximum(counts, 1))
        per_block = np.repeat(per_stage, counts)
        if self._block_times is None:
            self._block_times = per_block.copy()
            return
        b = self.estimate_beta
        self._block_times[:] = (1.0 - b) * self._block_times + b * per_block

    def query_executor(self, queries: Sequence[jnp.ndarray],
                       slowdown_schedule,
                       max_batch: int = 1) -> "_LiveQueryExecutor":
        """This engine's :class:`~repro.workloads.QueryExecutor` half,
        for external drivers (``repro.cluster`` builds one per replica
        and feeds it through the shared run loop).  ``queries`` may be
        a *growing* sequence: the cluster appends each routed query
        before it executes."""
        return _LiveQueryExecutor(self, queries, slowdown_schedule,
                                  max_batch=max_batch)

    def serve(self, queries: Sequence[jnp.ndarray],
              slowdown_schedule,
              workload: Union[str, Workload, None] = "closed",
              workload_kwargs: Optional[dict] = None,
              max_batch: int = 1,
              admission: Union[str, object, None] = None,
              admission_kwargs: Optional[dict] = None,
              trace_mode: str = "dense",
              metrics_sink=None,
              sink_interval: Optional[int] = None) -> PipelineTrace:
        """Serve ``queries`` under ``slowdown_schedule(q) -> per-EP
        slowdown factors (>= 1.0)``.

        ``workload`` picks the arrival process (``repro.workloads``):
        the default closed loop executes back-to-back exactly as before;
        open-loop workloads (rates in queries/second of wall-clock
        service time) additionally report queueing delay and offered
        vs. achieved load in the returned trace.

        ``max_batch > 1`` turns on batched serving (docs/WORKLOADS.md
        "Batching & the fast path"): queued arrivals are stacked and
        executed together, up to ``max_batch`` per dispatch, so bursts
        amortize stage dispatch + sync overhead instead of queueing
        one-by-one.  Batches never span an interference edge or a
        rebalance, and only queries that have already arrived join
        (a closed loop therefore still serves one at a time).

        ``admission`` selects a :mod:`repro.control` admission policy
        (e.g. ``admission="slo_shed", admission_kwargs={"slo":
        0.25}`` — SLO in wall-clock seconds); shed queries are turned
        away before touching the executor and reported through the
        trace's shed/goodput surface (docs/CONTROL.md).

        ``trace_mode="streaming"`` / ``metrics_sink`` select the
        flat-memory telemetry path (docs/TELEMETRY.md), identically to
        the simulator: streaming runs return a
        :class:`~repro.telemetry.StreamingTrace`, sinks receive
        periodic snapshots in either mode.
        """
        live = self.query_executor(queries, slowdown_schedule,
                                   max_batch=max_batch)
        trace = run_pipeline(live, self.runtime, len(queries),
                             workload=workload,
                             workload_kwargs=workload_kwargs,
                             scheduler_name=self.scheduler,
                             admission=admission,
                             admission_kwargs=admission_kwargs,
                             trace_mode=trace_mode,
                             metrics_sink=metrics_sink,
                             sink_interval=sink_interval)
        # The peak reference only exists after measurement: stamp it
        # post-hoc so the trace's SLO metrics work like the simulator's.
        trace.peak_throughput = self.estimated_peak_throughput()
        return trace
