"""Live serving engine: ODIN/LLS against *measured* stage times.

This is the end-to-end integration of the paper's technique: real JAX
model execution through the recompile-free pipeline executor, per-stage
wall-clock monitoring, online interference detection, and stepwise
rebalancing — one exploration trial per (serially processed) query,
exactly as in the simulator, but with physical time.

Interference is injected as per-EP slowdown factors (emulating co-located
tenants; the measured-database builder in tools/ uses real co-running
stressor processes instead).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lls import LLSController
from repro.core.odin import OdinController
from repro.core.pipeline_state import balanced_config, throughput
from repro.pipeline.executor import LocalPipelineExecutor, MeasuredTimeSource


@dataclasses.dataclass
class ServeMetrics:
    latencies: np.ndarray
    stage_time_max: np.ndarray
    serial_mask: np.ndarray
    configs: List[List[int]]
    num_rebalances: int

    @property
    def throughputs(self) -> np.ndarray:
        return 1.0 / np.maximum(self.stage_time_max, 1e-12)

    def summary(self) -> Dict[str, float]:
        return {
            "mean_latency_s": float(self.latencies.mean()),
            "p99_latency_s": float(np.percentile(self.latencies, 99)),
            "mean_throughput_qps": float(self.throughputs.mean()),
            "rebalances": self.num_rebalances,
            "serial_frac": float(self.serial_mask.mean()),
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, num_eps: int,
                 scheduler: str = "odin", alpha: int = 10,
                 rel_threshold: float = 0.15):
        self.cfg = cfg
        self.executor = LocalPipelineExecutor(cfg, params)
        self.num_eps = num_eps
        self.scheduler = scheduler
        if scheduler == "odin":
            self.controller = OdinController(alpha=alpha,
                                             rel_threshold=rel_threshold)
        elif scheduler == "lls":
            self.controller = LLSController(rel_threshold=rel_threshold)
        elif scheduler == "none":
            self.controller = None
        else:
            raise ValueError(scheduler)
        self.config = balanced_config(cfg.num_blocks, num_eps)
        self._explorer = None
        # EMA of measured per-block times feeds the scheduler's trial
        # evaluations between real executions.
        self._block_times: Optional[np.ndarray] = None

    def _update_block_estimates(self, config: Sequence[int],
                                stage_times: np.ndarray,
                                slowdowns: Sequence[float]) -> None:
        """Refresh per-block clean-time estimates from a measured query."""
        if self._block_times is None:
            self._block_times = np.full(self.cfg.num_blocks, 1e-3)
        lo = 0
        for s, c in enumerate(config):
            if c > 0:
                per_block = stage_times[s] / max(slowdowns[s], 1e-9) / c
                self._block_times[lo:lo + c] = (
                    0.5 * self._block_times[lo:lo + c] + 0.5 * per_block)
            lo += c

    def serve(self, queries: Sequence[jnp.ndarray],
              slowdown_schedule) -> ServeMetrics:
        """slowdown_schedule(q) -> per-EP slowdown factors (>= 1.0)."""
        n = len(queries)
        latencies = np.zeros(n)
        tmax = np.zeros(n)
        serial = np.zeros(n, bool)
        configs: List[List[int]] = []
        rebalances = 0

        for q, tokens in enumerate(queries):
            slow = np.asarray(slowdown_schedule(q), float)
            source = (MeasuredTimeSource(self._block_times, slow)
                      if self._block_times is not None else None)

            if self._explorer is not None and source is not None:
                trial_cfg = self._explorer.step(source)
                t0 = time.perf_counter()
                _, st = self.executor.run_query(tokens, trial_cfg,
                                                slowdowns=slow)
                latencies[q] = time.perf_counter() - t0
                tmax[q] = st[np.nonzero(trial_cfg)[0]].max()
                serial[q] = True
                configs.append(list(trial_cfg))
                self._update_block_estimates(trial_cfg, st, slow)
                if self._explorer.done:
                    self.config = self._explorer.result().config
                    self.controller.finish(self.config, source)
                    self._explorer = None
                continue

            t0 = time.perf_counter()
            _, st = self.executor.run_query(tokens, self.config,
                                            slowdowns=slow)
            latencies[q] = time.perf_counter() - t0
            live = [i for i, c in enumerate(self.config) if c > 0]
            tmax[q] = st[live].max()
            configs.append(list(self.config))
            self._update_block_estimates(self.config, st, slow)

            if self.controller is not None:
                source = MeasuredTimeSource(self._block_times, slow)
                if self.controller.detect(self.config, source):
                    rebalances += 1
                    self._explorer = self.controller.make_explorer(self.config)

        return ServeMetrics(latencies=latencies, stage_time_max=tmax,
                            serial_mask=serial, configs=configs,
                            num_rebalances=rebalances)
