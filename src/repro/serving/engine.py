"""Live serving engine: scheduler policies against *measured* stage times.

This is the end-to-end integration of the paper's technique: real JAX
model execution through the recompile-free pipeline executor, per-stage
wall-clock monitoring, online interference detection, and stepwise
rebalancing — one exploration trial per (serially processed) query.

The detect → explore → commit state machine is the same
:class:`~repro.schedulers.runtime.RebalanceRuntime` the simulator
drives, and the per-query loop itself is the same
:func:`repro.workloads.run_pipeline`: the engine only supplies physical
time (a :class:`~repro.pipeline.executor.MeasuredTimeSource` built from
the EMA of measured per-block times) where the simulator supplies
database lookups.  Any registered policy name — or a custom
:class:`~repro.schedulers.base.SchedulerPolicy` instance — plugs in, as
does any registered workload (closed-loop by default; ``poisson`` /
``bursty`` / ``trace`` for open-loop runs with queueing accounting in
wall-clock seconds).

Detection runs at the shared
:data:`repro.schedulers.DEFAULT_REL_THRESHOLD` in the detector's
EMA/hysteresis mode (measured times jitter query-to-query; see
``repro.schedulers.defaults``).

Interference is injected as per-EP slowdown factors (emulating co-located
tenants; the measured-database builder in tools/ uses real co-running
stressor processes instead).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pipeline_state import balanced_config, throughput
from repro.pipeline.executor import LocalPipelineExecutor, MeasuredTimeSource
from repro.schedulers.base import SchedulerPolicy
from repro.schedulers.defaults import DEFAULT_ALPHA, MEASURED_DETECTOR_MODE
from repro.schedulers.registry import make_scheduler
from repro.schedulers.runtime import RebalanceRuntime, RuntimeStep
from repro.workloads import (
    PipelineTrace,
    QueryRecord,
    Workload,
    run_pipeline,
)

#: Deprecated alias — ``serve()`` now returns the unified
#: :class:`repro.workloads.PipelineTrace` (same summary keys plus the
#: queueing/SLO surface the simulator already had).
ServeMetrics = PipelineTrace


class _LiveQueryExecutor:
    """Engine-side :class:`~repro.workloads.QueryExecutor`.

    Each query runs for real through the
    :class:`~repro.pipeline.executor.LocalPipelineExecutor`; the
    scheduler runtime is polled with a
    :class:`~repro.pipeline.executor.MeasuredTimeSource` over the
    engine's online per-block time estimates.  Until the first query has
    been measured there are no estimates to reason over, so
    ``begin_query`` returns ``None`` and the query runs steady.
    """

    def __init__(self, engine: "ServingEngine",
                 queries: Sequence[jnp.ndarray], slowdown_schedule):
        self.engine = engine
        self.queries = queries
        self.schedule = slowdown_schedule
        self._slow: Optional[np.ndarray] = None

    def begin_query(self, q: int) -> Optional[MeasuredTimeSource]:
        self._slow = np.asarray(self.schedule(q), float)
        if self.engine._block_times is None:
            return None
        return MeasuredTimeSource(self.engine._block_times, self._slow)

    def execute(self, q: int, step: RuntimeStep) -> QueryRecord:
        eng = self.engine
        first_measurement = eng._block_times is None
        t0 = time.perf_counter()
        _, st = eng.executor.run_query(self.queries[q], step.config,
                                       slowdowns=self._slow)
        latency = time.perf_counter() - t0
        live = [i for i, c in enumerate(step.config) if c > 0]
        tmax = float(st[live].max())
        eng._update_block_estimates(step.config, st, self._slow)
        if first_measurement:
            # Arm detection against this query's measured conditions,
            # so interference beginning at the very next query is a
            # shift from this baseline rather than the baseline.
            eng.runtime.arm(
                MeasuredTimeSource(eng._block_times, self._slow))
        return QueryRecord(service_latency=latency,
                           throughput=1.0 / max(tmax, 1e-12))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, num_eps: int,
                 scheduler: Union[str, SchedulerPolicy] = "odin",
                 alpha: int = DEFAULT_ALPHA,
                 rel_threshold: Optional[float] = None):
        self.cfg = cfg
        self.executor = LocalPipelineExecutor(cfg, params)
        self.num_eps = num_eps
        if isinstance(scheduler, str):
            self.policy = make_scheduler(scheduler, alpha=alpha,
                                         rel_threshold=rel_threshold,
                                         detector=MEASURED_DETECTOR_MODE)
            self.scheduler = scheduler
        else:
            self.policy = scheduler
            self.scheduler = getattr(scheduler, "name",
                                     type(scheduler).__name__)
        self._initial_config = balanced_config(cfg.num_blocks, num_eps)
        self.runtime = RebalanceRuntime(self.policy, self._initial_config)
        # EMA of measured per-block times feeds the scheduler's trial
        # evaluations between real executions.
        self._block_times: Optional[np.ndarray] = None

    @property
    def config(self) -> List[int]:
        """Current committed stage configuration."""
        return list(self.runtime.config)

    def estimated_peak_throughput(self) -> float:
        """Interference-free throughput of the starting configuration,
        from the online clean per-block estimates — the live analogue of
        the simulator's "executing alone" peak reference.  NaN until a
        query has been measured."""
        if self._block_times is None:
            return float("nan")
        clean = MeasuredTimeSource(self._block_times,
                                   np.ones(self.num_eps))
        return throughput(clean.stage_times(self._initial_config))

    def _update_block_estimates(self, config: Sequence[int],
                                stage_times: np.ndarray,
                                slowdowns: Sequence[float]) -> None:
        """Refresh per-block clean-time estimates from a measured query."""
        if self._block_times is None:
            self._block_times = np.full(self.cfg.num_blocks, 1e-3)
        lo = 0
        for s, c in enumerate(config):
            if c > 0:
                per_block = stage_times[s] / max(slowdowns[s], 1e-9) / c
                self._block_times[lo:lo + c] = (
                    0.5 * self._block_times[lo:lo + c] + 0.5 * per_block)
            lo += c

    def serve(self, queries: Sequence[jnp.ndarray],
              slowdown_schedule,
              workload: Union[str, Workload, None] = "closed",
              workload_kwargs: Optional[dict] = None) -> PipelineTrace:
        """Serve ``queries`` under ``slowdown_schedule(q) -> per-EP
        slowdown factors (>= 1.0)``.

        ``workload`` picks the arrival process (``repro.workloads``):
        the default closed loop executes back-to-back exactly as before;
        open-loop workloads (rates in queries/second of wall-clock
        service time) additionally report queueing delay and offered
        vs. achieved load in the returned trace.
        """
        live = _LiveQueryExecutor(self, queries, slowdown_schedule)
        trace = run_pipeline(live, self.runtime, len(queries),
                             workload=workload,
                             workload_kwargs=workload_kwargs,
                             scheduler_name=self.scheduler)
        # The peak reference only exists after measurement: stamp it
        # post-hoc so the trace's SLO metrics work like the simulator's.
        trace.peak_throughput = self.estimated_peak_throughput()
        return trace
