"""Live serving engine: scheduler policies against *measured* stage times.

This is the end-to-end integration of the paper's technique: real JAX
model execution through the recompile-free pipeline executor, per-stage
wall-clock monitoring, online interference detection, and stepwise
rebalancing — one exploration trial per (serially processed) query.

The detect → explore → commit state machine is the same
:class:`~repro.schedulers.runtime.RebalanceRuntime` the simulator drives:
the engine only supplies physical time (a
:class:`~repro.pipeline.executor.MeasuredTimeSource` built from the EMA
of measured per-block times) where the simulator supplies database
lookups.  Any registered policy name — or a custom
:class:`~repro.schedulers.base.SchedulerPolicy` instance — plugs in.

Interference is injected as per-EP slowdown factors (emulating co-located
tenants; the measured-database builder in tools/ uses real co-running
stressor processes instead).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pipeline_state import balanced_config
from repro.pipeline.executor import LocalPipelineExecutor, MeasuredTimeSource
from repro.schedulers.base import SchedulerPolicy
from repro.schedulers.registry import make_scheduler
from repro.schedulers.runtime import RebalanceRuntime, RuntimeStep


@dataclasses.dataclass
class ServeMetrics:
    latencies: np.ndarray
    stage_time_max: np.ndarray
    serial_mask: np.ndarray
    configs: List[List[int]]
    num_rebalances: int

    @property
    def throughputs(self) -> np.ndarray:
        return 1.0 / np.maximum(self.stage_time_max, 1e-12)

    def summary(self) -> Dict[str, float]:
        return {
            "mean_latency_s": float(self.latencies.mean()),
            "p99_latency_s": float(np.percentile(self.latencies, 99)),
            "mean_throughput_qps": float(self.throughputs.mean()),
            "rebalances": self.num_rebalances,
            "serial_frac": float(self.serial_mask.mean()),
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, num_eps: int,
                 scheduler: Union[str, SchedulerPolicy] = "odin",
                 alpha: int = 10, rel_threshold: float = 0.15):
        self.cfg = cfg
        self.executor = LocalPipelineExecutor(cfg, params)
        self.num_eps = num_eps
        if isinstance(scheduler, str):
            self.policy = make_scheduler(scheduler, alpha=alpha,
                                         rel_threshold=rel_threshold)
            self.scheduler = scheduler
        else:
            self.policy = scheduler
            self.scheduler = getattr(scheduler, "name",
                                     type(scheduler).__name__)
        self.runtime = RebalanceRuntime(
            self.policy, balanced_config(cfg.num_blocks, num_eps))
        # EMA of measured per-block times feeds the scheduler's trial
        # evaluations between real executions.
        self._block_times: Optional[np.ndarray] = None

    @property
    def config(self) -> List[int]:
        """Current committed stage configuration."""
        return list(self.runtime.config)

    def _update_block_estimates(self, config: Sequence[int],
                                stage_times: np.ndarray,
                                slowdowns: Sequence[float]) -> None:
        """Refresh per-block clean-time estimates from a measured query."""
        if self._block_times is None:
            self._block_times = np.full(self.cfg.num_blocks, 1e-3)
        lo = 0
        for s, c in enumerate(config):
            if c > 0:
                per_block = stage_times[s] / max(slowdowns[s], 1e-9) / c
                self._block_times[lo:lo + c] = (
                    0.5 * self._block_times[lo:lo + c] + 0.5 * per_block)
            lo += c

    def serve(self, queries: Sequence[jnp.ndarray],
              slowdown_schedule) -> ServeMetrics:
        """slowdown_schedule(q) -> per-EP slowdown factors (>= 1.0)."""
        n = len(queries)
        latencies = np.zeros(n)
        tmax = np.zeros(n)
        serial = np.zeros(n, bool)
        configs: List[List[int]] = []
        rebalances0 = self.runtime.num_rebalances

        for q, tokens in enumerate(queries):
            slow = np.asarray(slowdown_schedule(q), float)
            # Until the first query has been measured there are no block
            # estimates for the policy to reason over: run steady.
            first_measurement = self._block_times is None
            if first_measurement:
                step = RuntimeStep(list(self.runtime.config), serial=False)
            else:
                source = MeasuredTimeSource(self._block_times, slow)
                step = self.runtime.poll(source)

            t0 = time.perf_counter()
            _, st = self.executor.run_query(tokens, step.config,
                                            slowdowns=slow)
            latencies[q] = time.perf_counter() - t0
            live = [i for i, c in enumerate(step.config) if c > 0]
            tmax[q] = st[live].max()
            serial[q] = step.serial
            configs.append(list(step.config))
            self._update_block_estimates(step.config, st, slow)
            if first_measurement:
                # Arm detection against this query's measured conditions,
                # so interference beginning at the very next query is a
                # shift from this baseline rather than the baseline.
                self.runtime.arm(
                    MeasuredTimeSource(self._block_times, slow))

        return ServeMetrics(latencies=latencies, stage_time_max=tmax,
                            serial_mask=serial, configs=configs,
                            num_rebalances=(self.runtime.num_rebalances
                                            - rebalances0))
