from repro.serving.engine import (  # noqa: F401
    ServeMetrics,
    ServingEngine,
)
