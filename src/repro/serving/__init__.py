from repro.serving.engine import ServeMetrics, ServingEngine  # noqa: F401
