"""Analytic FLOP / HBM-byte model per (architecture × input shape).

Why this exists: XLA's ``compiled.cost_analysis()`` counts each
while-loop body exactly once (verified in-tree — a 10-iteration
``lax.scan`` of a matmul reports 1× the matmul FLOPs), so anything under
``lax.scan`` / ``lax.map`` / ``fori_loop`` (our block stack, the chunked
flash attention, the SSD chunk scan) is undercounted.  The dry-run
unrolls the *block* loop so the HLO collective schedule is exact, but the
roofline compute/memory terms come from this module: exact matmul-level
accounting, cross-validated against ``cost_analysis`` on fully-unrolled
reduced configs (see tests/test_analytic.py).

Conventions:
* FLOPs: 2·M·N·K per matmul.  Causal attention counts the executed
  (block-culled) score/PV work: the chunked implementation skips fully
  masked tiles, so ≈ half the S² work at long S, and the sliding-window
  variant only touches ~window·S.
* Train = fwd + 2×bwd (+1 extra fwd when remat=True).
* HBM bytes: every parameter read once per fwd pass (bf16); optimizer
  update reads/writes params + m/v in fp32; activations counted at the
  block interfaces (the dominant intra-block traffic is modeled per
  component); decode reads the whole KV cache once per step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import InputShape, ModelConfig

BF16 = 2
F32 = 4


def _attn_ctx_tokens(S: int, window, causal: bool) -> float:
    """Average attended keys per query under block culling."""
    if window is not None:
        w = min(window, S)
        # query i attends min(i+1, w) keys
        return (w * (w + 1) / 2 + (S - w) * w) / S if causal else min(2 * w, S)
    return (S + 1) / 2 if causal else S


@dataclasses.dataclass
class CostBreakdown:
    flops_fwd: float
    bytes_fwd: float            # params + activations traffic, one fwd
    param_bytes: float
    kv_bytes_step: float = 0.0  # decode: cache read+write per step

    def totals(self, mode: str, remat: bool = True) -> Dict[str, float]:
        if mode == "train":
            fwd_mult = 4.0 if remat else 3.0   # fwd + 2 bwd (+ remat fwd)
            flops = self.flops_fwd * fwd_mult
            # params bf16 read (fwd+bwd) + grad write + AdamW fp32 m/v
            # read+write + param read/write
            opt_bytes = self.param_bytes / BF16 * (2 * BF16 + 4 * F32 + 2 * F32)
            bytes_ = self.bytes_fwd * fwd_mult + opt_bytes
        else:
            flops = self.flops_fwd
            bytes_ = self.bytes_fwd + self.kv_bytes_step
        return {"flops": flops, "bytes": bytes_}


def analytic_cost(cfg: ModelConfig, shape: InputShape) -> CostBreakdown:
    B, S = shape.global_batch, shape.seq_len
    mode = shape.mode
    d, h = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    V = cfg.vocab_size

    if mode == "decode":
        T = B            # tokens processed this step
        S_ctx = S        # cache length attended
        seq_for_acts = 1
    else:
        T = B * S
        S_ctx = S
        seq_for_acts = S

    flops = 0.0
    act_bytes = 0.0
    param_bytes = 0.0
    kv_bytes = 0.0

    def matmul(t, din, dout):
        nonlocal flops, act_bytes, param_bytes
        flops += 2.0 * t * din * dout
        act_bytes += (t * din + t * dout) * BF16
        param_bytes += din * dout * BF16

    # ---- embeddings ------------------------------------------------------
    if not cfg.embedding_inputs or mode == "decode":
        act_bytes += T * d * BF16           # gather output
        param_bytes += V * d * BF16
    # ---- blocks -----------------------------------------------------------
    for bi in range(cfg.num_blocks):
        for i, kind in enumerate(cfg.layer_pattern):
            if kind == "attn":
                matmul(T, d, (nq + 2 * nkv) * h)          # qkv
                ctx = _attn_ctx_tokens(S_ctx, cfg.sliding_window, cfg.causal) \
                    if mode != "decode" else _attn_ctx_tokens(
                        S_ctx, cfg.sliding_window, True)
                if mode == "decode":
                    ctx = (min(cfg.sliding_window, S_ctx)
                           if cfg.sliding_window else S_ctx)
                flops += 2.0 * T * nq * h * ctx * 2        # scores + PV
                act_bytes += T * nq * h * BF16 * 2
                matmul(T, nq * h, d)                       # out proj
                if mode == "decode":
                    # read whole cache + write one slot
                    kv_bytes += 2 * B * S_ctx * nkv * h * BF16
                elif mode == "prefill" and cfg.is_decoder:
                    kv_bytes += 2 * B * S * nkv * h * BF16  # cache write
            else:  # mamba2
                s = cfg.ssm
                din = s.d_inner(d)
                H = s.num_heads(d)
                P = s.head_dim
                N = s.d_state
                matmul(T, d, 2 * din + 2 * N + H)          # in_proj
                flops += 2.0 * T * (din + 2 * N) * s.d_conv  # conv
                if mode == "decode":
                    # recurrent step: state update + readout
                    flops += T * H * P * N * 4.0
                    kv_bytes += 2 * B * H * P * N * BF16
                else:
                    cs = min(s.chunk_size, S)
                    # dual form per chunk: CBᵀ + (L∘CB)X + state write/read
                    flops += 2.0 * T * cs * N              # C·Bᵀ
                    flops += 2.0 * T * cs * H * P          # (L∘CB)·X
                    flops += 2.0 * T * N * H * P * 2       # states in/out
                matmul(T, din, d)                          # out_proj
                param_bytes += (s.d_conv * (din + 2 * N) + 3 * H) * BF16
            # ---- FFN ------------------------------------------------------
            if cfg.family == "ssm":
                continue
            if cfg.moe is not None and cfg.sublayer_is_moe(i):
                m = cfg.moe
                flops += 2.0 * T * d * m.num_experts       # router
                param_bytes += d * m.num_experts * F32
                routed_t = T * m.num_experts_per_tok * m.capacity_factor
                flops += 2.0 * routed_t * d * m.d_expert * 3
                act_bytes += routed_t * (2 * d + m.d_expert) * BF16
                param_bytes += m.num_experts * 3 * d * m.d_expert * BF16
                if m.num_shared_experts:
                    matmul(T, d, m.num_shared_experts * m.d_shared * 3)
            elif cfg.d_ff > 0:
                matmul(T, d, cfg.d_ff * 3)
            act_bytes += T * d * BF16 * 4                  # norms/residuals
    # ---- head --------------------------------------------------------------
    # decoders emit last-position logits at prefill; encoders emit all
    head_t = T if (mode == "train" or not cfg.is_decoder) else B
    flops += 2.0 * head_t * d * V
    act_bytes += head_t * (d + V) * BF16
    param_bytes += d * V * BF16

    return CostBreakdown(
        flops_fwd=flops,
        bytes_fwd=act_bytes + param_bytes,
        param_bytes=param_bytes,
        kv_bytes_step=kv_bytes,
    )


def analytic_totals(cfg: ModelConfig, shape: InputShape,
                    remat: bool = True) -> Dict[str, float]:
    return analytic_cost(cfg, shape).totals(shape.mode, remat=remat)
