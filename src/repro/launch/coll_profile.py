"""Collective profiler: rank collective ops in a compiled module by
loop-multiplied payload bytes, with op metadata (source of the gather),
plus the per-layer collective *cost model* the sharded serving path
consumes (:func:`layer_coll_costs` → ``MeshSpec.coll_costs``,
docs/SHARDING.md)."""
from __future__ import annotations

import re
from collections import defaultdict
from typing import List, Optional, Tuple

import numpy as np

from repro.launch.roofline import (
    _line_collective,
    _split_computations,
    _trip_count,
)


def layer_coll_costs(cfg, batch: int = 1, seq: int = 128,
                     bandwidth: float = 4.0e10,
                     dtype_bytes: int = 4,
                     hlo_text: Optional[str] = None) -> np.ndarray:
    """Per-layer collective cost profile (seconds) for mesh-sliced stages.

    A stage holding ``m > 1`` devices data-parallelizes its blocks and
    re-materializes the activations at each layer hand-off with a ring
    all-gather; the per-layer payload is the activation tile,
    ``batch x seq x d_model x dtype_bytes`` bytes, moved at ``bandwidth``
    bytes/s.  The ring factor ``(m - 1) / m`` and any contention
    inflation are applied downstream by
    :func:`repro.core.mesh.mesh_stage_times` — this profile is the
    *clean single-hop* cost only, so one profile serves every
    (assignment, interference) combination.

    ``hlo_text`` (a compiled module dump) refines the estimate: the
    summed loop-multiplied collective bytes from :func:`top_collectives`
    are spread evenly over the layers, replacing the analytic payload.
    The result feeds ``MeshSpec(coll_costs=...)`` directly.
    """
    L = int(cfg.num_blocks)
    if hlo_text is not None:
        rows = top_collectives(hlo_text, k=10 ** 6)
        total_bytes = float(sum(b for b, _ in rows))
        if total_bytes > 0.0:
            return np.full(L, total_bytes / L / float(bandwidth))
    payload = float(batch) * float(seq) * float(cfg.d_model) * dtype_bytes
    return np.full(L, payload / float(bandwidth))


def top_collectives(hlo_text: str, k: int = 15) -> List[Tuple[float, str]]:
    comps = _split_computations(hlo_text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else None
    while_re = re.compile(
        r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
    call_re = re.compile(
        r"(?:to_apply|body|condition|branch_computations)="
        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
    rows = []

    def walk(name, mult):
        for line in comps.get(name, "").splitlines():
            stripped = line.lstrip()
            lc = _line_collective(stripped)
            if lc:
                meta = re.search(r'op_name="([^"]*)"', stripped)
                op = meta.group(1) if meta else stripped[:80]
                rows.append((lc[1] * mult, lc[0], mult, op))
            wm = while_re.search(stripped)
            if wm:
                walk(wm.group(2), mult * _trip_count(comps.get(wm.group(1), "")))
                continue
            cm = call_re.search(stripped)
            if cm and "while(" not in stripped:
                for callee in re.split(r",\s*", cm.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        walk(callee, mult)

    if entry:
        walk(entry, 1)
    rows.sort(reverse=True)
    agg = defaultdict(float)
    for b, kind, mult, op in rows:
        agg[(kind, op)] += b
    out = sorted(((v, f"{kind:20s} {op}") for (kind, op), v in agg.items()),
                 reverse=True)
    return out[:k]
