"""Collective profiler: rank collective ops in a compiled module by
loop-multiplied payload bytes, with op metadata (source of the gather)."""
from __future__ import annotations

import re
from collections import defaultdict
from typing import List, Tuple

from repro.launch.roofline import (
    _line_collective,
    _split_computations,
    _trip_count,
)


def top_collectives(hlo_text: str, k: int = 15) -> List[Tuple[float, str]]:
    comps = _split_computations(hlo_text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else None
    while_re = re.compile(
        r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
    call_re = re.compile(
        r"(?:to_apply|body|condition|branch_computations)="
        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
    rows = []

    def walk(name, mult):
        for line in comps.get(name, "").splitlines():
            stripped = line.lstrip()
            lc = _line_collective(stripped)
            if lc:
                meta = re.search(r'op_name="([^"]*)"', stripped)
                op = meta.group(1) if meta else stripped[:80]
                rows.append((lc[1] * mult, lc[0], mult, op))
            wm = while_re.search(stripped)
            if wm:
                walk(wm.group(2), mult * _trip_count(comps.get(wm.group(1), "")))
                continue
            cm = call_re.search(stripped)
            if cm and "while(" not in stripped:
                for callee in re.split(r",\s*", cm.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        walk(callee, mult)

    if entry:
        walk(entry, 1)
    rows.sort(reverse=True)
    agg = defaultdict(float)
    for b, kind, mult, op in rows:
        agg[(kind, op)] += b
    out = sorted(((v, f"{kind:20s} {op}") for (kind, op), v in agg.items()),
                 reverse=True)
    return out[:k]
