"""Sharding rules: parameters, optimizer state, caches, batches.

Strategy (DESIGN.md §5): a 2D/3D FSDP×TP grid.

* last dim of every ≥2-D weight → ``model`` (tensor parallel),
* second-to-last dim → ``data`` (+``pod``) (ZeRO-3 / FSDP),
* stacked-block leading dim and 1-D params stay replicated,
* MoE expert dim → ``model`` when divisible (expert parallelism takes
  precedence over per-expert TP),
* batch dims of activations / caches → ``data`` (+``pod``); for the
  single-request long-context shape the cache sequence dim is sharded
  instead (see ``cache_pspec``).

Divisibility is checked per-leaf; non-divisible dims fall back to
replication, so every (arch × mesh) combination lowers.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axes


def _axsize(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh, axes) -> bool:
    return dim % _axsize(mesh, axes) == 0


def param_pspec(path: str, leaf, mesh, *, stacked: bool,
                strategy: str = "tp", cfg=None) -> P:
    """PartitionSpec for one parameter leaf.

    ``stacked``: leaf has a leading num_blocks axis (never sharded —
    it is the lax.scan axis and the pipeline-stage axis).

    Strategies (see EXPERIMENTS.md §Perf):
      * "tp"       — tensor/expert parallel on ``model`` only; weights are
                     replicated over the data axes.  XLA then communicates
                     *activations* once per TP matmul instead of
                     re-gathering weights/activations per loop body.
      * "zero3"    — v0 baseline: additionally shard a weight dim over the
                     data axes (kept for the recorded baseline comparison).
      * "dp_seq"   — weights fully replicated; the batch/sequence of the
                     activations carry all the parallelism (for archs
                     whose head counts don't divide the model axis —
                     sharded heads otherwise force per-chunk score
                     all-reduces inside the attention loops).
      * "fsdp_all" — weights sharded over ``model`` for storage, batch
                     sharded over data×model: XLA gathers *weights* once
                     per layer (ZeRO-3 over the flattened mesh).  Optimal
                     when weight bytes/layer < routed-activation bytes
                     (deepseek-style fine-grained MoE at large batch).
    """
    fsdp = data_axes(mesh)
    shape = leaf.shape
    lead = 1 if stacked else 0
    body = shape[lead:]
    spec: list = [None] * len(shape)
    if len(body) == 0:
        return P()
    if strategy == "dp_seq":
        # weights sharded over the data axes for *storage* (per-layer
        # weight gather ~= params bytes per pass — cheap for <=32B-class
        # models); activations carry batch(data) x sequence(model).
        if len(body) >= 2:
            dims = sorted(range(len(body)), key=lambda i: -body[i])
            for i in dims:
                if _fits(body[i], mesh, fsdp):
                    spec[lead + i] = fsdp
                    break
        return P(*spec)
    if len(body) == 1:
        return P(*spec)  # norms, biases, A_log ... replicated

    # Mamba2 sublayer (§Perf iteration 6): B/C projections and the
    # depthwise conv are shared across heads — shard them and every head
    # re-gathers the SSM state; replicate them (they are tiny) and
    # row-shard out_proj so its all-reduce is the only collective.
    if "mixer/wB" in path or "mixer/wC" in path or "mixer/conv" in path:
        return P(*spec)
    if path.endswith("mixer/out_proj") and _fits(body[0], mesh, "model"):
        spec[lead] = "model"
        return P(*spec)

    is_expert = any(k in path for k in ("ffn/wi", "ffn/wg", "ffn/wo")) \
        and len(body) == 3  # [E, d, f] / [E, f, d]

    # GQA-aware attention TP (§Perf iteration 5): sharding the flattened
    # (heads·head_dim) projection dim when heads % model_size != 0 splits
    # heads mid-head_dim and forces per-chunk score all-reduces inside the
    # attention loops (1.3 TB/chip on qwen2 prefill).  Shard by whole
    # heads when divisible, otherwise replicate (k/v projections are
    # small under GQA).
    if cfg is not None and "mixer/w" in path and strategy in ("tp", "zero3"):
        msize = _axsize(mesh, "model")
        is_kv = path.endswith("mixer/wk") or path.endswith("mixer/wv")
        heads = cfg.num_kv_heads if is_kv else cfg.num_heads
        if heads and heads % msize == 0:
            # wq/wk/wv: [.., d, H*h] -> model on out dim; wo: [.., H*h, d]
            dim = lead + (len(body) - 1 if not path.endswith("mixer/wo")
                          else len(body) - 2)
            spec[dim] = "model"
            return P(*spec)
        if path.endswith("mixer/wo") and cfg.num_heads % msize == 0:
            spec[lead] = "model"
            return P(*spec)
        return P(*spec)  # replicate this projection

    if strategy == "fsdp_all":
        # storage sharding only: largest body dim -> model
        dims = sorted(range(len(body)), key=lambda i: -body[i])
        for i in dims:
            if _fits(body[i], mesh, "model"):
                spec[lead + i] = "model"
                break
        return P(*spec)

    if is_expert and _fits(body[0], mesh, "model"):
        # expert parallelism (+ v0: FSDP over the expert's input dim)
        spec[lead] = "model"
        if strategy == "zero3" and _fits(body[1], mesh, fsdp):
            spec[lead + 1] = fsdp
        return P(*spec)

    # generic: last dim -> model (+ v0: previous dim -> fsdp)
    if _fits(body[-1], mesh, "model"):
        spec[lead + len(body) - 1] = "model"
    if strategy == "zero3" and len(body) >= 2 and _fits(body[-2], mesh, fsdp):
        spec[lead + len(body) - 2] = fsdp
    return P(*spec)


def params_shardings(params_shapes: Any, mesh, strategy: str = "tp",
                     cfg=None) -> Any:
    """Pytree of NamedShardings matching a params pytree (of shapes)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path, simple=True, separator="/")
        stacked = key.startswith("blocks/")
        spec = param_pspec(key, leaf, mesh, stacked=stacked,
                           strategy=strategy, cfg=cfg)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _zero1_spec(leaf, base: P, mesh) -> P:
    """Add data-axis sharding to the largest still-unsharded dim (ZeRO-1:
    optimizer moments are sharded even where params are replicated)."""
    fsdp = data_axes(mesh)
    spec = list(base) + [None] * (leaf.ndim - len(base))
    best, best_dim = None, -1
    for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
        if s is None and _fits(dim, mesh, fsdp) and dim > best_dim:
            best, best_dim = i, dim
    if best is not None and best_dim >= _axsize(mesh, fsdp):
        spec[best] = fsdp
    return P(*spec)


def opt_state_shardings(opt_shapes: Any, params_sh: Any, mesh,
                        strategy: str = "tp") -> Any:
    """m/v: param shardings + ZeRO-1 data-axis sharding; count replicated."""
    if strategy == "zero3":
        mv = params_sh
    else:
        mv = jax.tree.map(
            lambda leaf, sh: NamedSharding(
                mesh, _zero1_spec(leaf, sh.spec, mesh)),
            opt_shapes["m"], params_sh)
    return {
        "m": mv,
        "v": mv,
        "count": NamedSharding(mesh, P()),
    }


def batch_pspec(batch_shapes: Any, mesh, strategy: str = "tp") -> Any:
    """Shard the inputs' batch dim on the data axes.

    * "dp_seq":   additionally shard the sequence dim on ``model``.
    * "fsdp_all": shard the batch over data×model (flattened mesh).
    """
    fsdp = data_axes(mesh)

    def spec(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        s: list = [None] * leaf.ndim
        if strategy == "fsdp_all" and _fits(leaf.shape[0], mesh,
                                            fsdp + ("model",)):
            s[0] = fsdp + ("model",)
            return NamedSharding(mesh, P(*s))
        if _fits(leaf.shape[0], mesh, fsdp):
            s[0] = fsdp
        if (strategy == "dp_seq" and leaf.ndim >= 2
                and leaf.shape[1] > 1 and _fits(leaf.shape[1], mesh, "model")):
            s[1] = "model"
        return NamedSharding(mesh, P(*s))

    return jax.tree.map(spec, batch_shapes)


def cache_shardings(cache_shapes: Any, mesh, batch: int) -> Any:
    """Decode-cache shardings.

    Layout per leaf: [L, B, ...].  Batch dim -> data axes when divisible
    (decode_32k); the attention-cache *sequence* dim -> ``model``
    (sequence-parallel cache residency: decode attention becomes a
    distributed softmax whose reductions are KB-sized — sharding kv-heads
    or head_dim instead makes XLA all-gather the whole cache per block,
    §Perf iteration 2).  For the single-request long-context shape (B=1)
    the sequence dim is additionally sharded over the data axes.
    Mamba state caches ([L,B,H,P,N] / conv [L,B,W,C]) shard heads /
    channels on ``model``.
    """
    fsdp = data_axes(mesh)

    def spec(leaf):
        shape = leaf.shape
        s: list = [None] * len(shape)
        is_kv = len(shape) == 5 and shape[2] >= 1024  # [L,B,S,kv,h]
        batched = len(shape) >= 2 and _fits(shape[1], mesh, fsdp)
        if batched:
            s[1] = fsdp  # batch
        if is_kv:
            if batched and _fits(shape[2], mesh, "model"):
                s[2] = "model"   # sequence
            elif not batched:
                # B=1 long-context: spread the sequence over the mesh
                if _fits(shape[2], mesh, fsdp + ("model",)):
                    s[2] = fsdp + ("model",)
                elif _fits(shape[2], mesh, "model"):
                    s[2] = "model"
        elif len(shape) == 5 and _fits(shape[2], mesh, "model"):
            # mamba ssm state heads [L,B,H,P,N] — must match the
            # model-sharded channels of wx, or every block re-gathers
            # the state (§Perf iteration 6)
            s[2] = "model"
        elif len(shape) == 4 and _fits(shape[3], mesh, "model"):
            s[3] = "model"   # mamba conv channels [L,B,W,C]
        return NamedSharding(mesh, P(*s))

    return jax.tree.map(spec, cache_shapes)
