"""Serving driver: ODIN-managed inference pipeline under interference.

    python -m repro.launch.serve --arch qwen3-4b --scheduler odin \
        --eps 4 --queries 100 [--alpha 10]

Runs the reduced config of the chosen family through the recompile-free
pipeline executor on the host device, injects interference episodes, and
reports latency / throughput / rebalance statistics for ODIN vs LLS.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import ARCH_IDS, get_smoke_config
from repro.control import available_admission_policies
from repro.core.database import paper_scenarios
from repro.models import Model
from repro.qos import available_tiers
from repro.schedulers import available_schedulers
from repro.serving import ServingEngine
from repro.workloads import available_workloads, make_lengths


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    # Every registered policy is servable except the oracle, which needs
    # a caller-supplied solver (the simulator wires one in).
    ap.add_argument("--scheduler", default="odin",
                    choices=tuple(n for n in available_schedulers()
                                  if n != "oracle"))
    ap.add_argument("--alpha", type=int, default=10)
    ap.add_argument("--eps", type=int, default=4)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--blocks", type=int, default=0,
                    help="override block count (0 = config default)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--freq", type=int, default=25,
                    help="interference frequency period (queries)")
    ap.add_argument("--duration", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workload", default="closed",
                    choices=tuple(n for n in available_workloads()
                                  if n != "trace"),
                    help="arrival process (docs/WORKLOADS.md); open-loop "
                         "runs report queueing delay separately")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="open-loop arrival rate, q/s (poisson rate / "
                         "bursty burst_rate; bursty idles between bursts)")
    ap.add_argument("--max-batch", type=int, default=1,
                    help="batched serving: stack up to N queued arrivals "
                         "per dispatch (docs/WORKLOADS.md; >1 only pays "
                         "off for open-loop workloads with bursts)")
    ap.add_argument("--batching", default="none",
                    choices=("none", "drain", "continuous"),
                    help="formed-dispatch mode (docs/WORKLOADS.md "
                         "'Continuous batching & length buckets'): drain "
                         "runs length-bucketed batches to completion, "
                         "continuous admits arrivals into the in-flight "
                         "batch at stage boundaries; --max-batch caps the "
                         "dispatch width")
    ap.add_argument("--buckets", default="",
                    help="length buckets for --batching: 'pow2:lo:hi', a "
                         "comma list like '64,128,256', or empty for a "
                         "single bucket at the longest query")
    ap.add_argument("--lengths", default="fixed",
                    choices=("fixed", "uniform", "bimodal"),
                    help="per-query sequence-length distribution "
                         "(repro.workloads.lengths; anchored at --seq: "
                         "uniform draws [seq/4, seq], bimodal mixes seq/4 "
                         "and seq)")
    ap.add_argument("--admission", default="none",
                    choices=tuple(available_admission_policies()),
                    help="admission policy (docs/CONTROL.md); slo_shed / "
                         "adaptive_batch need --slo")
    ap.add_argument("--slo", type=float, default=0.0,
                    help="latency objective in seconds for --admission "
                         "slo_shed / adaptive_batch (0 = unset)")
    ap.add_argument("--trace-mode", default="dense",
                    choices=("dense", "streaming"),
                    help="streaming folds per-query telemetry into "
                         "constant-memory sketches/rollups instead of "
                         "dense arrays (docs/TELEMETRY.md)")
    ap.add_argument("--metrics-export", default="", metavar="PATH",
                    help="write the final metrics registry to PATH after "
                         "the run (.prom/.txt Prometheus text exposition, "
                         "anything else JSON; needs --trace-mode "
                         "streaming; docs/TELEMETRY.md)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve a fleet of N engine replicas behind a "
                         "router (docs/CLUSTER.md); hedging and "
                         "health-aware routing need N >= 2")
    ap.add_argument("--router", default="round_robin",
                    help="fleet router registry name (docs/CLUSTER.md; "
                         "'edf' and 'downgrade' are tier-aware, "
                         "docs/QOS.md); needs --replicas >= 2 or "
                         "--configs")
    ap.add_argument("--tiers", default="", metavar="NAMES",
                    help="comma list of QoS tier presets, e.g. "
                         "'interactive,best_effort' (docs/QOS.md): "
                         "arrivals are stamped with tier/deadline/value "
                         "and the trace grows per-tier accounting")
    ap.add_argument("--configs", default="", metavar="ARCHS",
                    help="comma list of arch ids, one per replica — a "
                         "heterogeneous fleet (docs/QOS.md); replicas "
                         "whose arch differs from the first are labeled "
                         "pool 'small' (the --router downgrade targets); "
                         "overrides --replicas")
    ap.add_argument("--faults", default="", metavar="SPEC",
                    help="fault plan spec, e.g. 'crash@50+20:r=0,"
                         "flaky@0+1000:p=0.05' (docs/FAULTS.md); windows "
                         "are query-indexed on a single engine and "
                         "wall-clock (open-loop workloads only) on a "
                         "--replicas fleet")
    ap.add_argument("--retries", type=int, default=-1, metavar="N",
                    help="per-query retry budget with exponential "
                         "backoff (docs/FAULTS.md); -1 leaves the fault "
                         "machinery unarmed")
    ap.add_argument("--hedge-after", type=float, default=0.0,
                    metavar="SECONDS",
                    help="hedge a dispatch to a healthy peer when its "
                         "projected wait exceeds this (docs/FAULTS.md; "
                         "needs --replicas >= 2)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    configs_list = [c.strip() for c in args.configs.split(",")
                    if c.strip()]
    if configs_list:
        unknown = [c for c in configs_list if c not in ARCH_IDS]
        if unknown:
            ap.error(f"--configs has unknown arch ids {unknown}; "
                     f"pick from {ARCH_IDS}")
        if args.replicas > 1 and args.replicas != len(configs_list):
            ap.error(f"--configs names {len(configs_list)} replicas but "
                     f"--replicas says {args.replicas}")
        args.replicas = len(configs_list)
        args.arch = configs_list[0]
    if args.tiers:
        bad = [t.strip() for t in args.tiers.split(",")
               if t.strip() not in available_tiers()]
        if bad:
            ap.error(f"--tiers has unknown presets {bad}; pick from "
                     f"{available_tiers()}")

    cfg = get_smoke_config(args.arch)
    if args.blocks:
        per = len(cfg.layer_pattern)
        cfg = dataclasses.replace(cfg, num_layers=args.blocks * per)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed), jnp.float32)

    rng = np.random.default_rng(args.seed)
    if cfg.embedding_inputs:
        raise SystemExit("serve demo uses token models; pick a non-VLM arch")
    if args.lengths == "fixed":
        lens = np.full(args.queries, args.seq, dtype=np.int64)
    else:
        kw = (dict(lo=max(1, args.seq // 4), hi=args.seq)
              if args.lengths == "uniform"
              else dict(short=max(1, args.seq // 4), long=args.seq,
                        p_long=0.2))
        lens = make_lengths(args.lengths, seed=args.seed,
                            **kw).sample(args.queries)
    # Heterogeneous fleets share the query stream, so token ids must be
    # valid for every replica's model: draw below the smallest vocab.
    vocab = cfg.vocab_size
    if configs_list:
        vocab = min(get_smoke_config(c).vocab_size for c in configs_list)
    queries = [jnp.asarray(rng.integers(0, vocab, (1, int(L))))
               for L in lens]

    scens = paper_scenarios()
    events = []
    for start in range(args.freq, args.queries, args.freq):
        events.append((start, start + args.duration,
                       int(rng.integers(args.eps)),
                       float(scens[rng.integers(len(scens))].slowdown_mean)))

    def schedule(q):
        slow = [1.0] * args.eps
        for s, e, ep, f in events:
            if s <= q < e:
                slow[ep] = f
        return slow

    eng = ServingEngine(cfg, params, num_eps=args.eps,
                        scheduler=args.scheduler, alpha=args.alpha)
    if args.batching == "none":
        # Bucketed serving pre-warms its own closed shape set
        # (configure_batching); the unbucketed path compiles each raw
        # length once, up front.
        for length in sorted({int(x) for x in lens}):
            eng.executor.ensure_warm(1, length)
    if args.workload == "closed":
        wl_kwargs = None             # --rate is irrelevant (and may be 0)
    else:
        wl_kwargs = dict(rate=args.rate, burst_rate=args.rate,
                         base_rate=args.rate / 10,
                         mean_burst=5.0 / args.rate * args.eps,
                         mean_gap=10.0 / args.rate * args.eps,
                         seed=args.seed)
    if args.admission in ("slo_shed", "adaptive_batch") and args.slo <= 0:
        ap.error(f"--admission {args.admission} requires --slo > 0")
    if args.metrics_export and args.trace_mode != "streaming":
        ap.error("--metrics-export needs --trace-mode streaming (the "
                 "dense trace has no metrics registry)")
    adm_kwargs = {"slo": args.slo} if args.slo > 0 else None
    faults = args.faults or None
    retries = None if args.retries < 0 else args.retries
    hedge_after = args.hedge_after if args.hedge_after > 0 else None
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if hedge_after is not None and args.replicas < 2:
        ap.error("--hedge-after needs --replicas >= 2 (hedging "
                 "dispatches to a healthy peer)")
    if args.replicas > 1:
        # Fleet path: same-arch replicas share the jitted executor but
        # keep their own runtime/detector/estimates (docs/CLUSTER.md);
        # --configs replicas of a different arch get their own model,
        # executor and warmed-shape caches (docs/QOS.md).
        if args.batching != "none" or args.max_batch > 1:
            ap.error("--replicas > 1 serves per-query; drop --batching "
                     "/ --max-batch")
        if args.metrics_export:
            ap.error("--metrics-export is single-engine only (the "
                     "fleet trace has no one registry to export)")
        if faults is not None and args.workload == "closed":
            ap.error("fleet fault windows are wall-clock "
                     "(docs/FAULTS.md); pick an open-loop --workload")
        archs = configs_list or [args.arch] * args.replicas
        # First engine per arch owns that arch's jitted executor and
        # warmed shapes; same-arch replicas share it, distinct archs
        # compile their own.
        lead = {args.arch: (cfg, params, eng)}
        engines, pools = [], []
        for arch in archs:
            if arch not in lead:
                c2 = get_smoke_config(arch)
                if args.blocks:
                    per = len(c2.layer_pattern)
                    c2 = dataclasses.replace(c2,
                                             num_layers=args.blocks * per)
                p2 = Model(c2).init_params(jax.random.PRNGKey(args.seed),
                                           jnp.float32)
                e2 = ServingEngine(c2, p2, num_eps=args.eps,
                                   scheduler=args.scheduler,
                                   alpha=args.alpha)
                for length in sorted({int(x) for x in lens}):
                    e2.executor.ensure_warm(1, length)
                lead[arch] = (c2, p2, e2)
            acfg, aparams, first = lead[arch]
            if not any(x is first for x in engines):
                e = first
            else:
                e = ServingEngine(acfg, aparams, num_eps=args.eps,
                                  scheduler=args.scheduler,
                                  alpha=args.alpha,
                                  executor=first.executor)
            engines.append(e)
            pools.append("default" if arch == archs[0] else "small")
        # The CLI drives the unified RunSpec path directly (docs/API.md)
        # — one declaration either way, and the spec's to_dict() is the
        # run's reproducible description.
        metrics = api.run(api.RunSpec(
            engines=engines, queries=queries, schedule=schedule,
            workload=api.WorkloadSpec(name=args.workload,
                                      kwargs=wl_kwargs),
            admission=api.AdmissionSpec(name=args.admission,
                                        kwargs=adm_kwargs),
            faults=api.FaultsSpec(plan=faults, hedge_after=hedge_after),
            retries=api.RetriesSpec(policy=retries),
            tiers=api.TiersSpec(spec=(args.tiers or None)),
            telemetry=api.TelemetrySpec(trace_mode=args.trace_mode),
            cluster=api.ClusterSpec(num_replicas=len(engines),
                                    router=args.router,
                                    pools=tuple(pools))))
        s = metrics.summary()
        s["final_config"] = None
    else:
        if args.router != "round_robin":
            ap.error("--router needs a fleet: pass --replicas >= 2 or "
                     "--configs")
        metrics = api.run(api.RunSpec(
            engine=eng, queries=queries, schedule=schedule,
            workload=api.WorkloadSpec(name=args.workload,
                                      kwargs=wl_kwargs),
            admission=api.AdmissionSpec(name=args.admission,
                                        kwargs=adm_kwargs),
            batching=api.BatchingSpec(
                mode=(None if args.batching == "none"
                      else args.batching),
                max_batch=args.max_batch,
                buckets=(args.buckets or None)),
            faults=api.FaultsSpec(plan=faults),
            retries=api.RetriesSpec(policy=retries),
            tiers=api.TiersSpec(spec=(args.tiers or None)),
            telemetry=api.TelemetrySpec(trace_mode=args.trace_mode)))
        s = metrics.summary()
        configs = metrics.configs
        s["final_config"] = configs[-1] if configs else None
    if args.metrics_export:
        from repro.telemetry import export_path_format, render_export
        path, fmt = export_path_format(args.metrics_export)
        with open(path, "w") as f:
            f.write(render_export(metrics.registry, fmt))
        if not args.json:
            print(f"metrics registry ({fmt}) -> {path}")
    if args.json:
        print(json.dumps(s))
    else:
        print(f"{cfg.name} scheduler={args.scheduler}")
        for k, v in s.items():
            print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
