import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the appropriate
step (train / prefill / decode) with ShapeDtypeStruct inputs (no
allocation), compiles, and records memory_analysis / cost_analysis /
collective schedule for the roofline report.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k \
        [--multi-pod] [--out results/dryrun]
    python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import (  # noqa: E402
    ARCH_IDS,
    INPUT_SHAPES,
    get_config,
    shape_is_applicable,
)
from repro.launch import analytic as an  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import sharding as sh  # noqa: E402
from repro.launch import steps as st     # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def auto_strategy(cfg, shape) -> str:
    """Pick the §Perf-winning strategy per (arch, shape).

    * train: fine-grained MoE (small experts, high top-k) moves fewer
      bytes gathering weights than dispatching tokens -> "fsdp_all";
      everything else "tp" (GQA-aware tensor/expert parallel + ZeRO-1).
    * inference: models whose bf16 params fit comfortably when stored
      sharded over the data axes run sequence-parallel "dp_seq"
      (attention fully local per chip); larger models run "tp".
    """
    pbytes = cfg.param_count() * 2
    if shape.mode == "train":
        if cfg.moe is not None and cfg.moe.d_expert <= 2048:
            return "fsdp_all"
        return "tp"
    if shape.mode == "prefill" and pbytes <= 70e9:
        return "dp_seq"
    return "tp"


def lower_and_compile(arch: str, shape_name: str, *, multi_pod: bool,
                      verbose: bool = True, unroll: bool = False,
                      strategy: str = "auto"):
    """Returns a result dict (lowered/compiled stats) for one combination."""
    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, why = shape_is_applicable(cfg0, shape)
    variant = None
    if not ok and shape.name == "long_500k" and cfg0.is_decoder:
        cfg = st.resolve_config(cfg0, shape)      # sliding-window variant
        variant = "sliding_window"
    elif not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}
    else:
        cfg = cfg0

    if strategy == "auto":
        strategy = auto_strategy(cfg, shape)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.perf_counter()

    # Pin activation sharding at block boundaries (Perf iteration 4):
    # batch on the data axes ("fsdp_all": over the whole mesh; "dp_seq":
    # + sequence on model).
    from jax.sharding import PartitionSpec as P
    from repro.models import sharding_ctx
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B = INPUT_SHAPES[shape_name].global_batch
    if strategy == "fsdp_all" and B % (mesh.size // 1) == 0:
        sharding_ctx.set_activation_spec(P(fsdp + ("model",), None, None))
    elif strategy == "dp_seq":
        sharding_ctx.set_activation_spec(P(fsdp, "model", None))
    elif B % (mesh.shape["data"] * (mesh.shape.get("pod", 1))) == 0:
        sharding_ctx.set_activation_spec(P(fsdp, None, None))
    else:
        sharding_ctx.set_activation_spec(None)

    params_sh = st.param_shapes(cfg)
    params_shd = sh.params_shardings(params_sh, mesh, strategy=strategy,
                                     cfg=cfg)
    specs = st.input_specs(cfg, shape)

    with jax.default_device(jax.devices()[0]):
        if shape.mode == "train":
            opt_sh = st.opt_state_shapes(params_sh)
            opt_shd = sh.opt_state_shardings(opt_sh, params_shd, mesh,
                                             strategy=strategy)
            batch_shd = sh.batch_pspec(specs["batch"], mesh, strategy=strategy)
            fn = st.make_train_step_fn(cfg, unroll=unroll)
            jfn = jax.jit(
                fn,
                in_shardings=(params_shd, opt_shd, batch_shd),
                out_shardings=(params_shd, opt_shd, None),
                donate_argnums=(0, 1))
            with mesh:
                lowered = jfn.lower(params_sh, opt_sh, specs["batch"])
        elif shape.mode == "prefill":
            fn = st.make_prefill_fn(cfg, shape, unroll=unroll)
            batch_shd = sh.batch_pspec(specs, mesh, strategy=strategy)
            jfn = jax.jit(
                lambda params, inputs: fn(params, **inputs),
                in_shardings=(params_shd, batch_shd))
            with mesh:
                lowered = jfn.lower(params_sh, specs)
        else:  # decode
            cache_shd = sh.cache_shardings(specs["cache"], mesh,
                                           shape.global_batch)
            tok_shd = sh.batch_pspec(specs["tokens"], mesh)
            fn = st.make_decode_step_fn(cfg, unroll=unroll)
            jfn = jax.jit(
                fn,
                in_shardings=(params_shd, tok_shd, cache_shd, None),
                out_shardings=(None, cache_shd),
                donate_argnums=(2,))
            with mesh:
                lowered = jfn.lower(params_sh, specs["tokens"],
                                    specs["cache"], specs["index"])

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    terms = rl.analyze(compiled, hlo, chips,
                       model_flops=rl.model_flops_for(cfg, shape),
                       analytic=an.analytic_totals(cfg, shape))

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy,
        "chips": chips,
        "variant": variant,
        "skipped": False,
        "mode": shape.mode,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
        },
        "roofline": {
            "flops": terms.flops,
            "bytes_accessed": terms.bytes_accessed,
            "hlo_flops": terms.hlo_flops,
            "hlo_bytes": terms.hlo_bytes,
            "collective_bytes": terms.coll_bytes,
            "collective_breakdown": terms.coll_breakdown,
            "t_compute_s": terms.t_compute,
            "t_memory_s": terms.t_memory,
            "t_collective_s": terms.t_collective,
            "bottleneck": terms.bottleneck,
            "model_flops": terms.model_flops,
            "useful_ratio": terms.useful_ratio,
        },
    }
    if verbose:
        per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / chips
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
              f"compile {t_compile:.1f}s, "
              f"args+temp/device {per_dev/2**30:.2f} GiB, "
              f"bottleneck={terms.bottleneck}", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  cost_analysis: flops={terms.flops:.3e} "
              f"bytes={terms.bytes_accessed:.3e} "
              f"coll={terms.coll_bytes:.3e}", flush=True)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="auto",
                    choices=("auto", "tp", "zero3", "dp_seq", "fsdp_all"))
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    combos = ([(args.arch, args.shape)] if not args.all else
              [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES])
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in combos:
        tag = f"{arch}__{shape}__{'2x16x16' if args.multi_pod else '16x16'}"
        try:
            res = lower_and_compile(arch, shape, multi_pod=args.multi_pod,
                                    strategy=args.strategy)
        except Exception as e:  # noqa: BLE001
            failures += 1
            res = {"arch": arch, "shape": shape, "error": str(e),
                   "traceback": traceback.format_exc()}
            print(f"[dryrun] FAIL {tag}: {e}", flush=True)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
