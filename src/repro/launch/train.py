"""Training driver.

    python -m repro.launch.train --arch qwen2-0.5b --steps 100 \
        [--smoke] [--seq 512] [--batch 8] [--checkpoint-dir ckpt/]

``--smoke`` selects the reduced config of the same family (CPU-runnable);
full configs are intended for the production mesh (see dryrun.py).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.training import AdamWConfig, train
from repro.training.data import SyntheticEmbeds, SyntheticLM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"training {cfg.name}: {cfg.num_blocks} blocks, "
          f"d_model={cfg.d_model}, ~{cfg.param_count()/1e6:.1f}M params")
    if cfg.embedding_inputs:
        data = SyntheticEmbeds(cfg.d_model, cfg.vocab_size, args.seq,
                               args.batch)
    else:
        data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    train(cfg, opt, iter(data), args.steps,
          dtype=jnp.float32,
          checkpoint_dir=args.checkpoint_dir,
          checkpoint_every=args.checkpoint_every)


if __name__ == "__main__":
    main()
