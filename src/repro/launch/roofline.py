"""Roofline-term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis`` supplies FLOPs and bytes accessed; collective bytes are
parsed from the (post-SPMD-partitioning) compiled HLO text by summing the
result-buffer sizes of every collective op.  Result-buffer bytes are the
per-participant payload actually moved onto the wire for all-gather /
all-to-all / collective-permute, and the received payload for
all-reduce / reduce-scatter — a uniform, reproducible proxy documented in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e per-chip constants (per prompt).
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,512,128]{2,1,0}"  or "f32[]"
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> its body text."""
    comps: Dict[str, str] = {}
    name = None
    buf: list = []
    for line in hlo_text.splitlines():
        m = re.match(r"(?:ENTRY\s+)?%([\w.\-]+)\s*\(", line)
        if (m and not line.startswith(" ")
                and line.rstrip().endswith("{")):
            name = m.group(1)
            buf = []
            continue
        if name is not None:
            if line.startswith("}"):
                comps[name] = "\n".join(buf)
                name = None
            else:
                buf.append(line)
    return comps


def _line_collective(stripped: str):
    """(kind, bytes) if the line is a collective op result, else None."""
    m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", stripped)
    if not m:
        return None
    rest = m.group(1)
    kind = None
    for k in _COLLECTIVES:
        if re.search(rf"\b{k}(-start|-done)?\(", rest):
            kind = k
            break
    if kind is None or f"{kind}-done(" in rest:
        return None  # -done pairs with -start; count once
    head = rest.split("(", 1)[0]
    return kind, sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))


def _trip_count(cond_text: str) -> int:
    """Trip count of a while loop from its condition computation: the
    comparison constant (max s32/u32 constant found)."""
    consts = [int(v) for v in
              re.findall(r"[su]\d+\[\]\s+constant\((-?\d+)\)", cond_text)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-chip collective payload bytes, loop-aware.

    XLA's cost analysis (and a flat text scan) counts a while-loop body
    once; scanned-block models execute it ``num_blocks`` times.  This
    parser walks the call graph: collectives inside a while body are
    multiplied by the loop's trip count (recovered from the condition
    computation's comparison constant); fusions/calls/conditionals are
    counted once.
    """
    comps = _split_computations(hlo_text)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    if m:
        entry = m.group(1)

    call_re = re.compile(
        r"(?:to_apply|body|condition|branch_computations)="
        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
    while_re = re.compile(
        r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")

    def walk(name: str, mult: int, out: Dict[str, int], seen) -> None:
        text = comps.get(name, "")
        for line in text.splitlines():
            stripped = line.lstrip()
            lc = _line_collective(stripped)
            if lc:
                out[lc[0]] += lc[1] * mult
            wm = while_re.search(stripped)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tc = _trip_count(comps.get(cond, ""))
                walk(body, mult * tc, out, seen)
                continue
            cm = call_re.search(stripped)
            if cm and "while(" not in stripped:
                for callee in re.split(r",\s*", cm.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        walk(callee, mult, out, seen)

    out = {k: 0 for k in _COLLECTIVES}
    if entry:
        walk(entry, 1, out, set())
    else:  # fallback: flat scan
        for line in hlo_text.splitlines():
            lc = _line_collective(line.lstrip())
            if lc:
                out[lc[0]] += lc[1]
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # global FLOPs (analytic when provided)
    bytes_accessed: float        # global HBM bytes (analytic when provided)
    hlo_flops: float             # raw cost_analysis (per-device × chips)
    hlo_bytes: float
    coll_bytes: float            # per-chip collective payload (from HLO)
    coll_breakdown: Dict[str, int]
    chips: int
    # derived (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def finalize(self) -> "RooflineTerms":
        self.t_compute = self.flops / (self.chips * PEAK_FLOPS)
        self.t_memory = self.bytes_accessed / (self.chips * HBM_BW)
        # collective bytes from the per-device HLO module are per-chip
        self.t_collective = self.coll_bytes / LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        if self.model_flops:
            self.useful_ratio = self.model_flops / max(self.flops, 1.0)
        return self


def analyze(compiled, hlo_text: str, chips: int,
            model_flops: Optional[float] = None,
            analytic: Optional[Dict[str, float]] = None) -> RooflineTerms:
    """``analytic``: {"flops", "bytes"} global totals from
    launch/analytic.py; they drive the compute/memory terms (HLO
    cost_analysis undercounts loop bodies — see analytic.py docstring).
    The collective term always comes from the compiled HLO schedule."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    coll = collective_bytes(hlo_text)
    # cost_analysis() on an SPMD executable reports the *per-device*
    # program (verified empirically); scale to global for the stored
    # numbers to follow the prompt's HLO_FLOPs/(chips × peak) convention.
    hlo_flops = float(cost.get("flops", 0.0)) * chips
    hlo_bytes = float(cost.get("bytes accessed", 0.0)) * chips
    return RooflineTerms(
        flops=analytic["flops"] if analytic else hlo_flops,
        bytes_accessed=analytic["bytes"] if analytic else hlo_bytes,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        chips=chips,
        model_flops=model_flops,
    ).finalize()


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train), 2·N·D (inference); N = active params."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per request
    return 2.0 * n * tokens
