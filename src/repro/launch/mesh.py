"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run driver must set
XLA_FLAGS before any JAX initialization.
"""
from __future__ import annotations

import jax


def build_mesh(shape, axes):
    """The one mesh-construction path (every builder here and
    ``repro.pipeline.spmd.stage_mesh`` routes through it — construct
    meshes nowhere else).

    Wraps jax.make_mesh across versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer JAX releases; all
    axes here are Auto, which is also the older default."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


#: Backward-compatible alias (pre-dedup private name).
_make_mesh = build_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: 16x16 = 256 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return build_mesh(shape, axes)


def make_stage_mesh(num_stages: int, *, model_parallel: int = 1):
    """Serving-pipeline mesh: ``stage`` = execution places (paper EPs),
    ``model`` = operator parallelism within an EP."""
    if model_parallel > 1:
        return build_mesh((num_stages, model_parallel), ("stage", "model"))
    return build_mesh((num_stages,), ("stage",))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
