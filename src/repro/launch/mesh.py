"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run driver must set
XLA_FLAGS before any JAX initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: 16x16 = 256 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_stage_mesh(num_stages: int, *, model_parallel: int = 1):
    """Serving-pipeline mesh: ``stage`` = execution places (paper EPs),
    ``model`` = operator parallelism within an EP."""
    if model_parallel > 1:
        return jax.make_mesh(
            (num_stages, model_parallel), ("stage", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
    return jax.make_mesh((num_stages,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
