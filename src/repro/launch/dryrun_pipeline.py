import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the ODIN pipeline-stage step on the production mesh.

The paper's technique itself — bind-to-stage pipeline execution with a
runtime block→stage assignment — lowered and compiled at production
scale: the mesh's ``data`` axis plays the EP/stage role (16 execution
places of 16 chips each single-pod; 2×16 EPs multi-pod), ``model`` is
operator parallelism within an EP (paper §2).  Proves the GPipe
shard_map schedule + collective_permute handoff + dynamic boundary
vector all lower at full scale.

    python -m repro.launch.dryrun_pipeline [--arch qwen3-32b] [--multi-pod]
"""
import argparse     # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch import roofline as rl         # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import param_shapes    # noqa: E402
from repro.pipeline.spmd import make_pipeline_fn, pack_stage_params  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-32b")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--mb-rows", type=int, default=2)
    ap.add_argument("--seq", type=int, default=1024)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    stage_axis = "data"            # EPs = 16-chip slices along this axis
    n_stages = mesh.shape[stage_axis]
    cap = -(-cfg.num_blocks // n_stages) * 2   # ODIN may double a stage

    params_sh = param_shapes(cfg)
    blocks_sh = params_sh["blocks"]
    stage_sh = jax.eval_shape(
        lambda bp: pack_stage_params(
            bp, [cfg.num_blocks // n_stages] * n_stages, cap), blocks_sh)
    counts = jax.ShapeDtypeStruct((n_stages,), jnp.int32)
    inputs = jax.ShapeDtypeStruct(
        (args.microbatch, args.mb_rows, args.seq, cfg.d_model), jnp.bfloat16)

    fn = make_pipeline_fn(cfg, mesh, stage_axis=stage_axis,
                          num_microbatches=args.microbatch, cap=cap)
    t0 = time.perf_counter()
    with mesh:
        lowered = fn.lower(stage_sh, counts, inputs)
        compiled = lowered.compile()
    dt = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    coll = rl.collective_bytes(compiled.as_text())
    print(f"[pipeline-dryrun] {cfg.name}: {n_stages} stages x "
          f"{mesh.size // n_stages} chips, cap={cap}, "
          f"compiled in {dt:.1f}s")
    print(f"  args/device: {mem.argument_size_in_bytes / 2**30:.2f} GiB")
    print("  collectives: " + ", ".join(
        f"{k}={v / 2**20:.1f}MiB" for k, v in coll.items() if v))
    print("  memory_analysis:", mem)


if __name__ == "__main__":
    main()
