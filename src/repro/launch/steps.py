"""Step builders + input specs for every (arch × input-shape) combination.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) for the inputs of the selected step kind:

* train:    {tokens|embeds, labels}                      -> metrics
* prefill:  {tokens|embeds}                              -> (logits, cache)
* decode:   {tokens, cache, index}                       -> (logits, cache)

The VLM/audio modality frontend is a stub per the carve-out: embedding
inputs arrive precomputed with shape [B, S, d_model].
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (
    InputShape,
    ModelConfig,
    long_context_variant,
    shape_is_applicable,
)
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


def resolve_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the sanctioned long-context variant where required."""
    ok, why = shape_is_applicable(cfg, shape)
    if ok:
        return cfg
    if shape.name == "long_500k" and cfg.is_decoder:
        return long_context_variant(cfg)
    raise ValueError(f"{cfg.name} x {shape.name} not applicable: {why}")


def param_shapes(cfg: ModelConfig) -> Any:
    model = Model(cfg)
    return jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), PARAM_DTYPE))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step inputs (excluding params/opt)."""
    B, S = shape.global_batch, shape.seq_len
    model = Model(cfg)
    if shape.mode == "train":
        batch: Dict[str, Any] = {"labels": _sds((B, S), jnp.int32)}
        if cfg.embedding_inputs:
            batch["embeds"] = _sds((B, S, cfg.d_model), PARAM_DTYPE)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
        return {"batch": batch}
    if shape.mode == "prefill":
        if cfg.embedding_inputs:
            return {"embeds": _sds((B, S, cfg.d_model), PARAM_DTYPE)}
        return {"tokens": _sds((B, S), jnp.int32)}
    if shape.mode == "decode":
        cache = jax.eval_shape(
            lambda: model.init_cache(B, S, CACHE_DTYPE))
        return {"tokens": _sds((B, 1), jnp.int32),
                "cache": cache,
                "index": _sds((), jnp.int32)}
    raise ValueError(shape.mode)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_train_step_fn(cfg: ModelConfig, opt: AdamWConfig = AdamWConfig(),
                       unroll: bool = False) -> Callable:
    model = Model(cfg, remat=True, unroll_blocks=unroll)

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            opt, params, grads, opt_state)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_fn(cfg: ModelConfig, shape: InputShape,
                    unroll: bool = False) -> Callable:
    model = Model(cfg, remat=True, unroll_blocks=unroll)
    B, S = shape.global_batch, shape.seq_len

    if cfg.is_decoder:
        def prefill(params, **inputs):
            cache = model.init_cache(B, S, CACHE_DTYPE)
            logits, cache = model.prefill(
                params, inputs.get("tokens"), inputs.get("embeds"), cache)
            return logits, cache
        return prefill

    # encoder-only (hubert): "prefill" = full encoder forward
    def encode(params, **inputs):
        logits, _ = model.forward(params, inputs.get("tokens"),
                                  inputs.get("embeds"))
        return logits
    return encode


def make_decode_step_fn(cfg: ModelConfig, unroll: bool = False) -> Callable:
    model = Model(cfg, unroll_blocks=unroll)

    def decode_step(params, tokens, cache, index):
        return model.decode_step(params, tokens, cache, index)

    return decode_step


def opt_state_shapes(params_sh: Any) -> Any:
    return jax.eval_shape(init_adamw, params_sh)
