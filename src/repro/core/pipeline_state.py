"""Pipeline configuration & cost primitives shared by ODIN / LLS / oracle.

A *configuration* ``C`` is a vector of contiguous layer counts per pipeline
stage (paper §3.2).  Stage ``i`` is bound to execution place ``i``
("bind-to-stage"); the interference state of the system is the per-EP
scenario vector ``k`` (index 0 = no interference).  All schedulers consume
stage times through a :class:`StageTimeSource`, so the simulator (database
lookups) and the live JAX runtime (measured times) are interchangeable.
"""
from __future__ import annotations

from typing import List, Protocol, Sequence

import numpy as np


class StageTimeSource(Protocol):
    """Anything that can report per-stage execution times for a config."""

    def stage_times(self, config: Sequence[int]) -> np.ndarray:
        """Execution time of each stage under the *current* interference."""
        ...


# ---------------------------------------------------------------------------
# Config helpers
# ---------------------------------------------------------------------------


def boundaries(config: Sequence[int]) -> List[int]:
    """Prefix boundaries: stage i owns layers [b[i], b[i+1])."""
    out = [0]
    for c in config:
        out.append(out[-1] + c)
    return out


def validate_config(config: Sequence[int], num_layers: int) -> None:
    if any(c < 0 for c in config):
        raise ValueError(f"negative stage count in {config}")
    if sum(config) != num_layers:
        raise ValueError(
            f"config {config} covers {sum(config)} layers, expected {num_layers}")


def balanced_config(num_layers: int, num_stages: int) -> List[int]:
    """Even split used as the interference-free starting configuration."""
    base, rem = divmod(num_layers, num_stages)
    return [base + (1 if i < rem else 0) for i in range(num_stages)]


# ---------------------------------------------------------------------------
# Throughput / latency model (paper §3.3)
# ---------------------------------------------------------------------------


def throughput(stage_times: np.ndarray) -> float:
    """T = 1 / max_i t_i  (empty stages contribute no time)."""
    t_max = float(np.max(stage_times)) if len(stage_times) else float("inf")
    if t_max <= 0.0:
        return float("inf")
    return 1.0 / t_max


def waiting_times(stage_times: np.ndarray) -> np.ndarray:
    """w_i = w_{i-1} + t_{i-1} - t_i, w_0 = 0 (clamped at 0).

    The clamp makes w a physical waiting time; the paper's recurrence is
    stated unclamped but only ratios enter the utilization formula.
    """
    w = np.zeros_like(stage_times)
    for i in range(1, len(stage_times)):
        w[i] = max(0.0, w[i - 1] + stage_times[i - 1] - stage_times[i])
    return w


def utilization(stage_times: np.ndarray) -> np.ndarray:
    """v_i = 1 - w_i / (w_i + t_i) with the paper's literal (unclamped)
    recurrence, which telescopes to w_i = t_0 - t_i and hence
    v_i = t_i / t_0: utilization is load relative to stage 0.  The
    slowest stage is the most utilized; empty stages get 0."""
    t0 = stage_times[0] if len(stage_times) else 1.0
    if t0 <= 0:
        nz = stage_times[stage_times > 0]
        t0 = float(nz[0]) if len(nz) else 1.0
    with np.errstate(invalid="ignore", divide="ignore"):
        return np.where(stage_times > 0, stage_times / t0, 0.0)


def pipelined_latency(stage_times: np.ndarray) -> float:
    """End-to-end latency of one query through the saturated pipeline.

    A bind-to-stage blocking pipeline at steady state advances on the
    bottleneck beat: every occupied stage holds a query for t_max before
    it can hand off downstream, so a query's sojourn is
    N_occupied × t_max.  (The w_i recurrence only models upstream-paced
    stalls and underestimates queueing behind late bottlenecks.)"""
    occ = stage_times[stage_times > 0]
    if len(occ) == 0:
        return 0.0
    return float(len(occ) * np.max(occ))


def serial_latency(stage_times: np.ndarray) -> float:
    """Latency while the pipeline is being rebalanced (queries run serially,
    paper §4.2 'Exploration overhead')."""
    return float(np.sum(stage_times))
