"""Layer-time interference database (paper §3.3 "Database Creation").

The paper measures each of the ``m`` network layers alone and under ``n``
colocation scenarios on a real platform, storing an ``m x (n+1)`` table
``D`` of execution times; the simulator then looks times up per
(layer, scenario-on-that-EP).

We reproduce the same structure with two sources:

* :func:`measured_database` — times real JAX layer executions on this
  container's CPU (the "real platform"), with interference emulated by a
  configurable slowdown model per scenario (we cannot pin iBench threads
  inside the sandbox; DESIGN.md §7.3).
* :func:`synthetic_database` — deterministic analytical generator used by
  tests and most benchmarks: per-layer base costs from a FLOP-ish profile,
  per-scenario slowdowns calibrated to the paper's Fig. 4 (1x–3.5x).

Scenario index 0 is always "no interference".
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Interference scenarios (paper Table 1): two iBench stressors (CPU, memBW)
# x thread counts / pinning variants = 12 scenarios.  The per-scenario
# slowdown factors below are calibrated to the impact range the paper
# reports in Fig. 4 for a single VGG16 layer (~1.05x to ~3.5x).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InterferenceScenario:
    name: str
    stressor: str        # "cpu" | "membw"
    threads: int
    pinned_share: float  # fraction of the EP's cores the stressor occupies
    slowdown_mean: float # mean multiplicative slowdown on a layer
    slowdown_std: float  # layer-to-layer variation


def paper_scenarios() -> List[InterferenceScenario]:
    """12 colocation scenarios mirroring Table 1."""
    out = []
    # CPU stressor at increasing thread counts / overlap with the EP cores.
    for threads, share, mean, std in [
            (1, 0.125, 1.07, 0.02), (2, 0.25, 1.18, 0.04),
            (4, 0.5, 1.45, 0.08), (8, 1.0, 1.95, 0.15),
            (16, 1.0, 2.60, 0.22), (32, 1.0, 3.20, 0.30)]:
        out.append(InterferenceScenario(
            f"ibench-cpu-{threads}t", "cpu", threads, share, mean, std))
    # memBW stressor: hits memory-bound layers harder.
    for threads, share, mean, std in [
            (1, 0.125, 1.10, 0.04), (2, 0.25, 1.28, 0.07),
            (4, 0.5, 1.65, 0.12), (8, 1.0, 2.25, 0.20),
            (16, 1.0, 2.95, 0.28), (32, 1.0, 3.50, 0.35)]:
        out.append(InterferenceScenario(
            f"ibench-membw-{threads}t", "membw", threads, share, mean, std))
    return out


# ---------------------------------------------------------------------------
# Database
# ---------------------------------------------------------------------------


class LayerDatabase:
    """``D[m, n+1]``: execution time of layer ``l`` under scenario ``k``.

    Column 0 is interference-free.  ``unit_names`` documents the pipeline
    units (layers or residual blocks).
    """

    def __init__(self, table: np.ndarray,
                 scenario_names: Sequence[str],
                 unit_names: Optional[Sequence[str]] = None,
                 model_name: str = ""):
        table = np.asarray(table, dtype=np.float64)
        if table.ndim != 2:
            raise ValueError("database table must be m x (n+1)")
        if np.any(table <= 0):
            raise ValueError("layer times must be positive")
        self.table = table
        self.scenario_names = list(scenario_names)
        if len(self.scenario_names) != table.shape[1]:
            raise ValueError("scenario_names length mismatch")
        self.unit_names = (list(unit_names) if unit_names is not None
                           else [f"layer{i}" for i in range(table.shape[0])])
        self.model_name = model_name

    # -- shapes ------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return self.table.shape[0]

    @property
    def num_scenarios(self) -> int:
        """n: interference scenarios, excluding the clean column."""
        return self.table.shape[1] - 1

    # -- lookups -------------------------------------------------------------
    def layer_time(self, layer: int, scenario: int) -> float:
        return float(self.table[layer, scenario])

    def prefix_times(self) -> np.ndarray:
        """``P[k, j]`` = sum of layer times ``[0, j)`` under scenario
        ``k`` — cached; the DP oracle is called once per distinct
        scenario vector and the prefix table never changes."""
        if not hasattr(self, "_prefix"):
            prefix = np.zeros((self.table.shape[1], self.num_layers + 1))
            prefix[:, 1:] = np.cumsum(self.table.T, axis=1)
            self._prefix = prefix
        return self._prefix

    def scenario_severities(self) -> np.ndarray:
        """Mean slowdown vs. clean per interference scenario (1..n).

        Ranks scenarios for the event advancer's overlap rule
        (:class:`repro.core.events.EventTimeline`): when several events
        hit one EP at once, the scenario with the largest measured mean
        slowdown wins.
        """
        return (self.table[:, 1:] / self.table[:, :1]).mean(axis=0)

    def stage_time(self, lo: int, hi: int, scenario: int) -> float:
        """Time of a stage owning layers [lo, hi) under one scenario."""
        return float(self.table[lo:hi, scenario].sum())

    def stage_times(self, config: Sequence[int],
                    scenarios: Sequence[int]) -> np.ndarray:
        """Per-stage times for config C with per-EP scenario vector k."""
        out = np.zeros(len(config))
        lo = 0
        for i, cnt in enumerate(config):
            out[i] = self.table[lo:lo + cnt, scenarios[i]].sum()
            lo += cnt
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({
                "model_name": self.model_name,
                "scenario_names": self.scenario_names,
                "unit_names": self.unit_names,
                "table": self.table.tolist(),
            }, f)

    @classmethod
    def load(cls, path: str) -> "LayerDatabase":
        with open(path) as f:
            d = json.load(f)
        return cls(np.array(d["table"]), d["scenario_names"],
                   d["unit_names"], d.get("model_name", ""))


# ---------------------------------------------------------------------------
# Synthetic (analytical) generation
# ---------------------------------------------------------------------------

# Relative per-unit cost profiles.  CNN profiles follow the canonical
# per-layer FLOP distributions; memory-boundedness drives sensitivity to
# the membw stressor.
_PROFILES: Dict[str, Dict] = {
    # VGG16: 13 conv + 3 FC, relative costs from the per-layer GFLOPs of
    # the canonical 224x224 network (conv1_1 0.17, conv1_2 3.7, ... ) with
    # the FC layers up-weighted for their weight-streaming memory cost.
    # The profile is *lumpy* (conv1_2 is ~20x conv1_1): single-layer moves
    # change stage times in large quanta, which is what separates ODIN's
    # plateau-escaping exploration from one-move greedy baselines.
    "vgg16": {
        "cost": [0.17, 3.70, 1.85, 3.70, 1.85, 3.70, 3.70, 1.85, 3.70,
                 3.70, 0.92, 0.92, 0.92, 1.40, 0.25, 0.06],
        "membound": [0.2] * 13 + [0.9, 0.9, 0.9],
    },
    # ResNet50: 50 conv layers; stage-structured bottleneck blocks — the
    # 1x1 reduce / 3x3 / 1x1 expand pattern cycles with heavy stage
    # transitions (stride-2 + projection shortcut layers).
    "resnet50": {
        "cost": [2.2] + [
            (1.0 if i % 3 == 1 else 2.4 if i % 3 == 2 else 1.2)
            * (2.0 if i in (2, 11, 23, 41) else 1.0)
            for i in range(1, 50)],
        "membound": [0.25 + 0.4 * ((i * 3) % 7) / 7 for i in range(50)],
    },
    # ResNet152 at residual-block granularity (paper §4.4): 52 units
    # (stem + 50 bottleneck blocks + head); block cost steps up at each
    # stage boundary where channel width doubles.
    "resnet152": {
        "cost": [1.8] + [
            (1.0 + 0.15 * ((i * 5) % 3))
            * (1.0 if i <= 3 else 1.3 if i <= 11 else 1.6 if i <= 47 else 2.1)
            for i in range(1, 51)] + [0.9],
        "membound": [0.25 + 0.4 * ((i * 3) % 7) / 7 for i in range(52)],
    },
}


def synthetic_database(model: str = "vgg16",
                       scenarios: Optional[List[InterferenceScenario]] = None,
                       base_time: float = 10.0,
                       seed: int = 0) -> LayerDatabase:
    """Deterministic m x (n+1) database for a named cost profile.

    ``membound`` modulates sensitivity: memBW stressors slow memory-bound
    layers more, CPU stressors slow compute-bound layers more — matching
    the per-scenario spread in the paper's Fig. 4.
    """
    if scenarios is None:
        scenarios = paper_scenarios()
    prof = _PROFILES[model]
    cost = np.asarray(prof["cost"], dtype=np.float64)
    memb = np.asarray(prof["membound"], dtype=np.float64)
    rng = np.random.default_rng(seed)
    m = len(cost)
    table = np.zeros((m, len(scenarios) + 1))
    table[:, 0] = base_time * cost
    for j, sc in enumerate(scenarios, start=1):
        if sc.stressor == "membw":
            sens = 0.5 + memb            # memory-bound layers suffer more
        else:
            sens = 1.5 - memb            # compute-bound layers suffer more
        factor = 1.0 + (sc.slowdown_mean - 1.0) * sens
        factor = factor * (1.0 + sc.slowdown_std * rng.standard_normal(m))
        # clamp to the paper's observed Fig. 4 impact range (~1.05x-3.5x)
        table[:, j] = table[:, 0] * np.clip(factor, 1.01, 3.5)
    names = ["none"] + [s.name for s in scenarios]
    return LayerDatabase(table, names, model_name=model)


def transformer_database(block_costs: Sequence[float],
                         scenarios: Optional[List[InterferenceScenario]] = None,
                         membound: Optional[Sequence[float]] = None,
                         seed: int = 0) -> LayerDatabase:
    """Database from measured/estimated per-block costs of a JAX model."""
    if scenarios is None:
        scenarios = paper_scenarios()
    cost = np.asarray(block_costs, dtype=np.float64)
    m = len(cost)
    memb = (np.asarray(membound, dtype=np.float64) if membound is not None
            else np.full(m, 0.5))
    rng = np.random.default_rng(seed)
    table = np.zeros((m, len(scenarios) + 1))
    table[:, 0] = cost
    for j, sc in enumerate(scenarios, start=1):
        sens = (0.5 + memb) if sc.stressor == "membw" else (1.5 - memb)
        factor = 1.0 + (sc.slowdown_mean - 1.0) * sens
        factor = factor * (1.0 + sc.slowdown_std * rng.standard_normal(m))
        table[:, j] = table[:, 0] * np.clip(factor, 1.01, 3.5)
    names = ["none"] + [s.name for s in scenarios]
    return LayerDatabase(table, names, model_name="transformer")
