"""Device-mesh slices per pipeline stage: the sharded cost model.

A pipeline *configuration* ``C`` (layers per stage) now composes with a
*mesh assignment* ``A`` — contiguous device ranges per stage, one slice
of a ``jax.sharding.Mesh`` each (docs/SHARDING.md).  Stage ``i`` with
``m_i = A[i]`` devices data-parallelizes its compute and pays a
collective (ring all-gather of its activations) to re-materialize the
hand-off:

    t_i(C, A) = compute_i(C) / m_i + coll_i(C) * ring(m_i) * f

where ``ring(m) = (m - 1) / m`` (the classic ring-collective factor —
zero for a single device), ``coll_i`` sums the per-layer collective
costs of the stage's layers (profiled via
:func:`repro.launch.coll_profile.layer_coll_costs`, or a flat per-layer
constant), and ``f`` is the *collective contention* factor a
``kind="mesh"`` :class:`~repro.core.events.InterferenceEvent` inflates
(1.0 when quiet).

Bit-identity invariant: an *unarmed* mesh (``mesh=None``) takes none of
the sharded code paths — traces are bit-identical to a pre-mesh build.
With ``m_i = 1`` everywhere the cost model itself is also float-exact
(``compute_i / 1.0 + 0.0``), but an *armed* all-ones mesh still swaps
the explorer's action space (``MeshOdinExplorer`` ranks candidate moves
instead of following Algorithm 1's heuristic order), so traces may
diverge once a rebalancing phase runs.  Every consumer (simulator, DP
oracle, explorer, live ``MeasuredTimeSource``) goes through
:func:`mesh_stage_times`, so the cost model has one home.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np


def ring_factor(m: int) -> float:
    """Ring-collective scaling: ``(m - 1) / m`` for ``m > 1``, else 0
    (a single-device stage runs no collective)."""
    m = int(m)
    return (m - 1) / m if m > 1 else 0.0


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Sharding options for one pipeline (sim or live).

    ``devices`` — total devices the stages share (each stage owns a
    contiguous slice, every stage at least one device).
    ``coll_cost`` — flat per-layer collective cost in the run's time
    unit; ``coll_costs`` overrides it with a per-layer profile (e.g.
    from :func:`repro.launch.coll_profile.layer_coll_costs`).
    """
    devices: int
    coll_cost: float = 0.0
    coll_costs: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if int(self.devices) < 1:
            raise ValueError(f"mesh devices must be >= 1, got "
                             f"{self.devices}")
        object.__setattr__(self, "devices", int(self.devices))
        if self.coll_costs is not None:
            object.__setattr__(
                self, "coll_costs",
                tuple(float(c) for c in self.coll_costs))

    def layer_costs(self, num_layers: int) -> np.ndarray:
        """Per-layer collective costs, validated against the model."""
        if self.coll_costs is not None:
            if len(self.coll_costs) != num_layers:
                raise ValueError(
                    f"mesh coll_costs names {len(self.coll_costs)} "
                    f"layers, model has {num_layers}")
            return np.asarray(self.coll_costs, dtype=np.float64)
        return np.full(num_layers, float(self.coll_cost))

    def coll_prefix(self, num_layers: int) -> np.ndarray:
        """Prefix sums of the per-layer collective costs (``P[j]`` =
        sum over layers ``[0, j)``), the shape the DP oracle consumes."""
        out = np.zeros(num_layers + 1)
        out[1:] = np.cumsum(self.layer_costs(num_layers))
        return out

    def to_dict(self) -> dict:
        d = {"devices": self.devices, "coll_cost": self.coll_cost}
        if self.coll_costs is not None:
            d["coll_costs"] = list(self.coll_costs)
        return d


def resolve_mesh(mesh: Union[None, int, dict, MeshSpec]) -> Optional[MeshSpec]:
    """Coerce the spec forms: ``None`` (unarmed), a device count, a
    kwargs dict, or a :class:`MeshSpec`."""
    if mesh is None:
        return None
    if isinstance(mesh, MeshSpec):
        return mesh
    if isinstance(mesh, int):
        return MeshSpec(devices=mesh)
    if isinstance(mesh, dict):
        d = dict(mesh)
        if "coll_costs" in d and d["coll_costs"] is not None:
            d["coll_costs"] = tuple(d["coll_costs"])
        return MeshSpec(**d)
    raise TypeError(f"mesh must be None, an int device count, a dict or "
                    f"a MeshSpec, got {type(mesh).__name__}")


def balanced_assignment(devices: int, num_stages: int) -> List[int]:
    """Even device split (mirrors ``balanced_config``); every stage
    gets at least one device."""
    if devices < num_stages:
        raise ValueError(f"{devices} devices cannot give each of "
                         f"{num_stages} stages a slice")
    base, rem = divmod(devices, num_stages)
    return [base + (1 if i < rem else 0) for i in range(num_stages)]


def validate_assignment(assignment: Sequence[int], devices: int) -> None:
    if any(int(m) < 1 for m in assignment):
        raise ValueError(f"every stage needs >= 1 device: {assignment}")
    if sum(int(m) for m in assignment) != devices:
        raise ValueError(f"assignment {list(assignment)} uses "
                         f"{sum(assignment)} devices, mesh has {devices}")


def assignments(devices: int, num_stages: int) -> Iterator[Tuple[int, ...]]:
    """All compositions of ``devices`` into ``num_stages`` positive
    parts — the (boundary, slice) oracle's slice axis.  C(D-1, S-1)
    tuples (35 for D=8, S=4), in lexicographic order (deterministic)."""
    for cuts in itertools.combinations(range(1, devices), num_stages - 1):
        bounds = (0,) + cuts + (devices,)
        yield tuple(bounds[i + 1] - bounds[i]
                    for i in range(num_stages))


def stage_collectives(layer_costs: np.ndarray,
                      config: Sequence[int]) -> np.ndarray:
    """Per-stage summed collective cost for a configuration (the
    analogue of ``LayerDatabase.stage_times`` for the collective
    column)."""
    out = np.zeros(len(config))
    lo = 0
    for i, cnt in enumerate(config):
        out[i] = layer_costs[lo:lo + cnt].sum()
        lo += cnt
    return out


def mesh_stage_times(compute: np.ndarray, config: Sequence[int],
                     assignment: Sequence[int], spec: MeshSpec,
                     coll_factor: float = 1.0,
                     layer_costs: Optional[np.ndarray] = None
                     ) -> np.ndarray:
    """Apply the sharded cost model to unsharded per-stage compute
    times: ``compute_i / m_i + coll_i * ring(m_i) * coll_factor``.
    ``layer_costs`` lets hot callers pass the cached per-layer profile
    instead of re-resolving it from the spec each query."""
    m = np.asarray(assignment, dtype=np.float64)
    ring = np.where(m > 1.0, (m - 1.0) / m, 0.0)
    if layer_costs is None:
        layer_costs = spec.layer_costs(int(sum(config)))
    coll = stage_collectives(layer_costs, config)
    return compute / np.maximum(m, 1.0) + coll * ring * float(coll_factor)


def collective_frac(compute: np.ndarray, config: Sequence[int],
                    assignment: Sequence[int], spec: MeshSpec,
                    coll_factor: float = 1.0,
                    layer_costs: Optional[np.ndarray] = None) -> float:
    """Fraction of the bottleneck stage's time spent in collectives
    (the per-query ``collective_frac`` trace column)."""
    if layer_costs is None:
        layer_costs = spec.layer_costs(int(sum(config)))
    total = mesh_stage_times(compute, config, assignment, spec,
                             coll_factor, layer_costs=layer_costs)
    i = int(np.argmax(total))
    if total[i] <= 0.0:
        return 0.0
    ring = ring_factor(int(assignment[i]))
    coll = (stage_collectives(layer_costs, config)[i]
            * ring * float(coll_factor))
    return float(coll / total[i])
