"""Least-Loaded Scheduling baseline (paper §3.3).

LLS computes per-stage utilization

    v_i = 1 - w_i / (w_i + t_i),   w_i = w_{i-1} + t_{i-1} - t_i,  w_0 = 0

and recursively moves one layer from the most-utilized to the
least-utilized stage until throughput starts decreasing (the last,
degrading move is reverted).  Like ODIN it only consumes observed stage
times.  Each tried move is one serially-processed query; the paper
reports ~1 query per LLS rebalancing phase.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.odin import RebalanceResult, Trial, _nonempty
from repro.core.pipeline_state import StageTimeSource, throughput, utilization


class LLSExplorer:
    """One greedy move per ``step()`` (one serial query each)."""

    serial = True   # each step costs one serially-processed query

    def __init__(self, config: Sequence[int], max_moves: int = 64):
        self.C = list(config)
        self.max_moves = max_moves
        self.T: Optional[float] = None
        self.trials: List[Trial] = []
        self.done = False

    def step(self, source: StageTimeSource) -> List[int]:
        assert not self.done
        C = self.C
        if self.T is None:
            self.T = throughput(source.stage_times(C))

        times = source.stage_times(C)
        v = utilization(times)
        donors = [i for i in _nonempty(C) if C[i] > 1]
        if not donors or len(self.trials) >= self.max_moves:
            self.done = True
            return list(C)
        # Most/least utilized with *first-index* tie-breaking (numpy argmax
        # semantics).  Ties are common: w_0 = 0 pins v_0 = 1, so stage 0
        # ties with the bottleneck — and the paper's measured overhead of
        # ~1 serially-processed query per LLS phase matches exactly this
        # behaviour (the first move usually fails and LLS stops).
        src = max(donors, key=lambda i: v[i])
        dst = min((i for i in range(len(C)) if i != src),
                  key=lambda i: v[i])
        C[src] -= 1
        C[dst] += 1
        T_new = throughput(source.stage_times(C))
        if T_new <= self.T:
            # "...recursively until the throughput starts decreasing"
            # (paper §3.3): the decrease is *observed*, i.e. the degrading
            # move has already been applied — LLS stops here and keeps it.
            self.T = T_new
            self.trials.append(Trial(list(C), T_new, False))
            self.done = True
        else:
            self.T = T_new
            self.trials.append(Trial(list(C), T_new, True))
        return list(C)

    def result(self) -> RebalanceResult:
        return RebalanceResult(list(self.C), float(self.T or 0.0),
                               list(self.trials))


def lls_rebalance(config: Sequence[int], source: StageTimeSource,
                  max_moves: int = 64) -> RebalanceResult:
    ex = LLSExplorer(config, max_moves)
    while not ex.done:
        ex.step(source)
    return ex.result()


# The online wrapper (shared detection + explorer factory) lives in
# repro.schedulers as LLSPolicy; ``LLSController`` stays importable.


def __getattr__(name: str):
    if name == "LLSController":
        from repro.schedulers.policies import LLSPolicy
        return LLSPolicy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
