"""Optimal-configuration oracle (the paper's "exhaustive search").

The paper's motivating example notes an exhaustive search over pipeline
configurations took 42.5 minutes.  Because stage time is additive over a
*contiguous* layer range evaluated under that EP's interference scenario,
the optimum is computable exactly in O(N · m²) by dynamic programming on
prefix boundaries — we use it as the "resource-constrained throughput"
reference of §4.3 (Fig. 9) without paying the brute-force cost.  A literal
brute-force enumerator is retained for cross-checking on small instances.
"""
from __future__ import annotations

import itertools
from functools import lru_cache
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.database import LayerDatabase


@lru_cache(maxsize=8)
def _invalid_mask(m: int) -> np.ndarray:
    """invalid[j, lo] masks cut points past the boundary (lo > j)."""
    return np.triu(np.ones((m + 1, m + 1), dtype=bool), k=1)


def optimal_partition(db: LayerDatabase,
                      scenarios: Sequence[int],
                      num_stages: int) -> Tuple[List[int], float]:
    """Min-bottleneck contiguous partition of m layers onto stages 0..N-1.

    Stage i evaluates its layers under ``scenarios[i]`` (bind-to-stage).
    Empty stages are allowed (the pipeline may shorten under interference).
    Returns (config, throughput).
    """
    m = db.num_layers
    N = num_stages
    # prefix[k][j] = sum of layer times [0, j) under scenario k
    prefix = db.prefix_times()

    INF = float("inf")
    # dp[i][j] = min bottleneck placing first j layers on stages [0, i)
    dp = np.full((N + 1, m + 1), INF)
    choice = np.zeros((N + 1, m + 1), dtype=np.int64)
    dp[0, 0] = 0.0
    invalid = _invalid_mask(m)
    for i in range(1, N + 1):
        pref = prefix[scenarios[i - 1]]
        # cost[j, lo] = max(dp[i-1, lo], time of layers [lo, j) on
        # stage i-1); argmin along lo keeps the first (lowest-lo)
        # minimum, matching a scalar scan's strict `<` tie-breaking.
        cost = np.maximum(dp[i - 1][None, :], pref[:, None] - pref[None, :])
        cost[invalid] = INF
        dp[i] = cost.min(axis=1)
        choice[i] = cost.argmin(axis=1)
    # Backtrack.
    config = [0] * N
    j = m
    for i in range(N, 0, -1):
        lo = int(choice[i, j])
        config[i - 1] = j - lo
        j = lo
    bottleneck = dp[N, m]
    return config, (1.0 / bottleneck if bottleneck > 0 else float("inf"))


def optimal_partition_mesh(db: LayerDatabase,
                           scenarios: Sequence[int],
                           num_stages: int,
                           mesh: "MeshSpec",
                           coll_factor: float = 1.0
                           ) -> Tuple[List[int], Tuple[int, ...], float]:
    """Min-bottleneck (boundary, slice) optimum (docs/SHARDING.md).

    Extends :func:`optimal_partition`'s action space with the mesh
    axis: enumerate every composition of ``mesh.devices`` into
    ``num_stages`` positive slices (C(D-1, S-1) of them), run the same
    boundary DP per composition under the sharded cost model — stage
    time ``(pref[hi] - pref[lo]) / m_i + (cpref[hi] - cpref[lo]) *
    ring(m_i) * coll_factor`` — and keep the global best.  Ties break
    toward the first composition in lexicographic order (deterministic).
    Returns ``(config, assignment, throughput)``.
    """
    from repro.core.mesh import assignments, ring_factor

    m = db.num_layers
    N = num_stages
    prefix = db.prefix_times()
    cpref = mesh.coll_prefix(m)

    INF = float("inf")
    invalid = _invalid_mask(m)
    best = None  # (bottleneck, config, assignment)
    for assign in assignments(mesh.devices, N):
        dp = np.full((N + 1, m + 1), INF)
        choice = np.zeros((N + 1, m + 1), dtype=np.int64)
        dp[0, 0] = 0.0
        for i in range(1, N + 1):
            pref = prefix[scenarios[i - 1]]
            ring = ring_factor(assign[i - 1]) * float(coll_factor)
            stage = ((pref[:, None] - pref[None, :]) / float(assign[i - 1])
                     + (cpref[:, None] - cpref[None, :]) * ring)
            cost = np.maximum(dp[i - 1][None, :], stage)
            cost[invalid] = INF
            dp[i] = cost.min(axis=1)
            choice[i] = cost.argmin(axis=1)
        bottleneck = dp[N, m]
        if best is None or bottleneck < best[0]:
            config = [0] * N
            j = m
            for i in range(N, 0, -1):
                lo = int(choice[i, j])
                config[i - 1] = j - lo
                j = lo
            best = (bottleneck, config, assign)
    bottleneck, config, assign = best
    return (config, assign,
            1.0 / bottleneck if bottleneck > 0 else float("inf"))


def brute_force_partition(db: LayerDatabase,
                          scenarios: Sequence[int],
                          num_stages: int) -> Tuple[List[int], float]:
    """Literal enumeration of all contiguous partitions (small m only)."""
    m = db.num_layers
    N = num_stages
    best_cfg, best_T = None, -1.0
    # boundaries: N-1 cut points in [0, m], non-decreasing
    for cuts in itertools.combinations_with_replacement(range(m + 1), N - 1):
        bounds = (0,) + cuts + (m,)
        if any(b2 < b1 for b1, b2 in zip(bounds, bounds[1:])):
            continue
        times = [db.stage_time(bounds[i], bounds[i + 1], scenarios[i])
                 for i in range(N)]
        t_max = max(t for t in times if t > 0) if any(times) else 0
        if t_max <= 0:
            continue
        T = 1.0 / t_max
        if T > best_T:
            best_T = T
            best_cfg = [bounds[i + 1] - bounds[i] for i in range(N)]
    return best_cfg, best_T
