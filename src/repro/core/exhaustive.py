"""Optimal-configuration oracle (the paper's "exhaustive search").

The paper's motivating example notes an exhaustive search over pipeline
configurations took 42.5 minutes.  Because stage time is additive over a
*contiguous* layer range evaluated under that EP's interference scenario,
the optimum is computable exactly in O(N · m²) by dynamic programming on
prefix boundaries — we use it as the "resource-constrained throughput"
reference of §4.3 (Fig. 9) without paying the brute-force cost.  A literal
brute-force enumerator is retained for cross-checking on small instances.
"""
from __future__ import annotations

import itertools
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.database import LayerDatabase


def optimal_partition(db: LayerDatabase,
                      scenarios: Sequence[int],
                      num_stages: int) -> Tuple[List[int], float]:
    """Min-bottleneck contiguous partition of m layers onto stages 0..N-1.

    Stage i evaluates its layers under ``scenarios[i]`` (bind-to-stage).
    Empty stages are allowed (the pipeline may shorten under interference).
    Returns (config, throughput).
    """
    m = db.num_layers
    N = num_stages
    # prefix[k][j] = sum of layer times [0, j) under scenario k
    prefix = np.zeros((db.table.shape[1], m + 1))
    prefix[:, 1:] = np.cumsum(db.table.T, axis=1)

    def seg(i: int, lo: int, hi: int) -> float:
        k = scenarios[i]
        return prefix[k, hi] - prefix[k, lo]

    INF = float("inf")
    # dp[i][j] = min bottleneck placing first j layers on stages [0, i)
    dp = np.full((N + 1, m + 1), INF)
    choice = np.zeros((N + 1, m + 1), dtype=np.int64)
    dp[0, 0] = 0.0
    for i in range(1, N + 1):
        for j in range(m + 1):
            best, arg = INF, 0
            for lo in range(j + 1):
                cost = max(dp[i - 1, lo], seg(i - 1, lo, j))
                if cost < best:
                    best, arg = cost, lo
            dp[i, j] = best
            choice[i, j] = arg
    # Backtrack.
    config = [0] * N
    j = m
    for i in range(N, 0, -1):
        lo = int(choice[i, j])
        config[i - 1] = j - lo
        j = lo
    bottleneck = dp[N, m]
    return config, (1.0 / bottleneck if bottleneck > 0 else float("inf"))


def brute_force_partition(db: LayerDatabase,
                          scenarios: Sequence[int],
                          num_stages: int) -> Tuple[List[int], float]:
    """Literal enumeration of all contiguous partitions (small m only)."""
    m = db.num_layers
    N = num_stages
    best_cfg, best_T = None, -1.0
    # boundaries: N-1 cut points in [0, m], non-decreasing
    for cuts in itertools.combinations_with_replacement(range(m + 1), N - 1):
        bounds = (0,) + cuts + (m,)
        if any(b2 < b1 for b1, b2 in zip(bounds, bounds[1:])):
            continue
        times = [db.stage_time(bounds[i], bounds[i + 1], scenarios[i])
                 for i in range(N)]
        t_max = max(t for t in times if t > 0) if any(times) else 0
        if t_max <= 0:
            continue
        T = 1.0 / t_max
        if T > best_T:
            best_T = T
            best_cfg = [bounds[i + 1] - bounds[i] for i in range(N)]
    return best_cfg, best_T
