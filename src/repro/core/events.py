"""Interference-event schedule + the per-query event advancer.

The simulator injects interference as :class:`InterferenceEvent`\\ s — a
scenario lands on one EP at a query index and lasts for a number of
queries (paper §4.2: one event every ``freq_period`` queries, lasting
``duration``).  With the paper's high-pressure settings (e.g. ``freq=2,
dur=100``) many events overlap on the same EP at once; an EP can only be
in *one* scenario, so the advancer must pick.

The old loop resolved overlaps by dict-overwrite order — whichever event
happened to come last in the list silently won.  :class:`EventTimeline`
makes the rule explicit and deterministic: **the highest-severity
scenario wins** (co-located stressors don't cancel each other; the
worst one dominates the EP).  Severity defaults to the scenario index
and can be supplied from the database's measured slowdowns
(:meth:`~repro.core.database.LayerDatabase.scenario_severities`); exact
severity ties break toward the higher scenario index.

Two event axes (both served by the same timeline):

* **query-indexed** (default) — ``start`` / ``duration`` count queries,
  the paper's §4.2 methodology and the natural axis for closed-loop
  runs, where query index *is* the clock.
* **time-indexed** (``EventTimeline(..., time_indexed=True)``) —
  ``start`` / ``duration`` are wall-clock times in the driver's time
  unit, and ``scenarios_at`` / ``next_change`` take a *time*, not a
  query index.  Open-loop runs advance the environment by each query's
  arrival time, so an event means "the stressor ran from t0 for Δt"
  regardless of how many queries happened to land inside — which is
  what lets replica-scoped events in a cluster hit one replica on the
  shared fleet clock (docs/CLUSTER.md).

Replica scoping: ``InterferenceEvent.replica`` targets one replica of a
:class:`~repro.cluster.Cluster` (``None`` — the default — applies to
every replica, and is what single-pipeline runs use).  The
:func:`events_for_replica` helper selects one replica's view of a
fleet-level event list.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, List, Optional, Sequence, Union

import numpy as np


@dataclasses.dataclass
class InterferenceEvent:
    #: Query index at which the event begins — or a wall-clock time when
    #: the owning timeline is ``time_indexed``.
    start: float
    #: Length in queries (or in time units when ``time_indexed``).
    duration: float
    ep: int
    scenario: int   # column in the database (>= 1)
    #: Cluster replica this event targets; ``None`` = every replica
    #: (and is the only sensible value for single-pipeline runs).
    replica: Optional[int] = None
    #: Interference class: ``"ep"`` (the default — a compute stressor
    #: landing on one EP, the paper's model) or ``"mesh"`` — contention
    #: on the *collectives* of a sharded run (docs/SHARDING.md): while
    #: active, every stage's collective time is inflated by ``factor``.
    #: Mesh events ignore ``ep``/``scenario`` (use 0 for both).
    kind: str = "ep"
    #: Collective-time inflation while a ``kind="mesh"`` event is
    #: active (>= 1.0); ignored for ``kind="ep"`` events.
    factor: float = 2.0

    @property
    def end(self) -> float:
        return self.start + self.duration


def events_for_replica(events: Sequence[InterferenceEvent],
                       replica: int) -> List[InterferenceEvent]:
    """One replica's view of a fleet event list: events targeting it
    plus fleet-wide (``replica=None``) events."""
    return [ev for ev in events
            if ev.replica is None or ev.replica == replica]


def generate_events(num_queries: int, num_eps: int, num_scenarios: int,
                    freq_period: int, duration: int,
                    seed: int = 0) -> List[InterferenceEvent]:
    """One event every ``freq_period`` queries on a random EP/scenario."""
    rng = np.random.default_rng(seed)
    events = []
    for start in range(freq_period, num_queries, freq_period):
        events.append(InterferenceEvent(
            start=start, duration=duration,
            ep=int(rng.integers(num_eps)),
            scenario=int(rng.integers(1, num_scenarios + 1))))
    return events


SeveritySpec = Union[None, Sequence[float], Callable[[int], float]]


class EventTimeline:
    """Per-query scenario advancer with a deterministic overlap rule.

    ``severity`` ranks scenarios when several events cover one EP at the
    same query: ``None`` ranks by scenario index, a sequence is indexed
    ``severity[scenario - 1]`` (scenario 0 is always "clean"), a
    callable is ``severity(scenario)``.  The winner is the max of
    ``(severity, scenario)`` — the tuple's second element makes exact
    severity ties deterministic.

    ``time_indexed=True`` reinterprets every event's ``start`` /
    ``duration`` as wall-clock values: ``scenarios_at`` and
    ``next_change`` then take a time (the driver passes each query's
    arrival time) instead of a query index, and ``next_change`` returns
    ``float('inf')`` past the last edge.
    """

    def __init__(self, events: Sequence[InterferenceEvent], num_eps: int,
                 severity: SeveritySpec = None,
                 time_indexed: bool = False):
        self.events = list(events)
        self.num_eps = num_eps
        self.time_indexed = bool(time_indexed)
        if severity is None:
            self._rank = lambda scenario: float(scenario)
        elif callable(severity):
            self._rank = severity
        else:
            table = np.asarray(severity, dtype=float)
            self._rank = lambda scenario: float(table[scenario - 1])
        # Sorted distinct event edges: the per-EP scenario vector is
        # piecewise-constant between consecutive edges, which is what
        # lets the run loop chunk environment-steady query ranges.
        # (Computed once; mutate ``events`` via a new EventTimeline.)
        self._edges = sorted({b for ev in self.events
                              for b in (ev.start, ev.end)})

    def next_change(self, q: float) -> float:
        """First query index (or time, when ``time_indexed``) ``> q``
        where the scenario vector can change (an event starts or ends);
        a large sentinel (``inf`` on the time axis) when no further edge
        exists.  ``scenarios_at`` is constant over
        ``[q, next_change(q))``."""
        i = bisect.bisect_right(self._edges, q)
        if i < len(self._edges):
            return self._edges[i]
        if self.time_indexed:
            return float("inf")
        return int(np.iinfo(np.int64).max)

    def scenarios_at(self, q: float) -> List[int]:
        """Per-EP scenario vector at query index — or time, when
        ``time_indexed`` — ``q`` (0 = no interference)."""
        best: List[Optional[tuple]] = [None] * self.num_eps
        for ev in self.events:
            if ev.kind == "ep" and ev.start <= q < ev.end:
                key = (self._rank(ev.scenario), ev.scenario)
                if best[ev.ep] is None or key > best[ev.ep][0]:
                    best[ev.ep] = (key, ev.scenario)
        return [0 if b is None else b[1] for b in best]

    def coll_factor_at(self, q: float) -> float:
        """Collective-time inflation at ``q``: the max ``factor`` over
        the active ``kind="mesh"`` events (worst stressor dominates,
        the same overlap rule ``scenarios_at`` uses), 1.0 when none is
        active.  Mesh-event edges participate in :meth:`next_change`,
        so chunked runs never span a factor change."""
        factor = 1.0
        for ev in self.events:
            if ev.kind == "mesh" and ev.start <= q < ev.end:
                factor = max(factor, float(ev.factor))
        return factor
