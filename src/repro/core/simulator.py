"""Query-driven pipeline simulator (paper §4 methodology).

Simulates an inference pipeline of N stages bound to N execution places
serving a window of queries (paper: 4000).  Interference events start
every ``freq_period`` queries on a random EP with a random scenario from
the database and last ``duration`` queries.  The scheduler under test is
any registered :mod:`repro.schedulers` policy (``odin`` / ``lls`` /
``oracle`` / ``none`` / ``hybrid`` / user plugins) and observes only
per-stage execution times; the per-query detect → explore → commit state
machine is the :class:`~repro.schedulers.runtime.RebalanceRuntime`
shared with the live serving engine, so during a rebalancing phase
queries are processed serially — one query per trial — exactly the
paper's exploration-overhead accounting.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core.database import LayerDatabase
from repro.core.exhaustive import optimal_partition
from repro.core.pipeline_state import (
    balanced_config,
    pipelined_latency,
    serial_latency,
    throughput,
)
from repro.schedulers.base import SchedulerPolicy
from repro.schedulers.registry import make_scheduler
from repro.schedulers.runtime import RebalanceRuntime


class SimTimeSource:
    """StageTimeSource backed by the database + current per-EP scenarios."""

    def __init__(self, db: LayerDatabase, scenarios: Sequence[int]):
        self.db = db
        self.scenarios = list(scenarios)

    def stage_times(self, config: Sequence[int]) -> np.ndarray:
        return self.db.stage_times(config, self.scenarios)


@dataclasses.dataclass
class InterferenceEvent:
    start: int      # query index at which the event begins
    duration: int   # in queries
    ep: int
    scenario: int   # column in the database (>= 1)

    @property
    def end(self) -> int:
        return self.start + self.duration


def generate_events(num_queries: int, num_eps: int, num_scenarios: int,
                    freq_period: int, duration: int,
                    seed: int = 0) -> List[InterferenceEvent]:
    """One event every ``freq_period`` queries on a random EP/scenario."""
    rng = np.random.default_rng(seed)
    events = []
    for start in range(freq_period, num_queries, freq_period):
        events.append(InterferenceEvent(
            start=start, duration=duration,
            ep=int(rng.integers(num_eps)),
            scenario=int(rng.integers(1, num_scenarios + 1))))
    return events


@dataclasses.dataclass
class SimResult:
    scheduler: str
    latencies: np.ndarray          # per query
    throughputs: np.ndarray        # per query (1 / bottleneck stage time)
    serial_mask: np.ndarray        # True where query was processed serially
    peak_throughput: float         # interference-free optimum
    rc_throughputs: np.ndarray     # resource-constrained optimum per query
    num_rebalances: int
    total_trials: int
    configs_trace: List[List[int]]
    mitigation_lengths: List[int]  # trials consumed per rebalancing phase

    @property
    def rebalance_fraction(self) -> float:
        return float(np.mean(self.serial_mask))

    @property
    def steady_throughput(self) -> float:
        """Mean throughput over pipelined (non-exploration) queries — the
        pipeline's operating rate, which is what the paper's Fig. 6
        reports (exploration overhead is Fig. 8's separate metric)."""
        pipe = self.throughputs[~self.serial_mask]
        return float(pipe.mean()) if len(pipe) else float(
            self.throughputs.mean())

    def tail_latency(self, pct: float = 99.0) -> float:
        return float(np.percentile(self.latencies, pct))

    def slo_violations(self, slo_level: float,
                       reference: str = "peak") -> float:
        """Fraction of queries with throughput below slo_level × reference."""
        if reference == "peak":
            target = slo_level * self.peak_throughput
            return float(np.mean(self.throughputs < target))
        elif reference == "resource_constrained":
            target = slo_level * self.rc_throughputs
            return float(np.mean(self.throughputs < target))
        raise ValueError(reference)


def simulate(db: LayerDatabase,
             num_eps: int,
             scheduler: Union[str, SchedulerPolicy] = "odin",
             alpha: int = 10,
             num_queries: int = 4000,
             freq_period: int = 10,
             duration: int = 10,
             seed: int = 0,
             rel_threshold: float = 0.02,
             events: Optional[List[InterferenceEvent]] = None,
             initial_config: Optional[List[int]] = None) -> SimResult:
    """Run one (scheduler, interference-setting) simulation.

    ``scheduler`` is a registry name (``repro.schedulers``) or an
    already-constructed :class:`SchedulerPolicy` instance.
    """
    if events is None:
        events = generate_events(num_queries, num_eps, db.num_scenarios,
                                 freq_period, duration, seed)
    config = (list(initial_config) if initial_config is not None
              else balanced_config(db.num_layers, num_eps))
    # Interference-free peak throughput of the starting configuration:
    # by assumption (§3.1) the initial config is the balanced optimum.
    clean = SimTimeSource(db, [0] * num_eps)
    # Start from the true clean optimum so "peak" matches the paper's
    # "throughput of the inference pipeline when executing alone".
    if initial_config is None:
        opt_cfg, _ = optimal_partition(db, [0] * num_eps, num_eps)
        config = opt_cfg
    peak = throughput(clean.stage_times(config))

    scenarios = [0] * num_eps
    source = SimTimeSource(db, scenarios)

    # Cache the oracle per scenario-vector (it is deterministic); it backs
    # both the resource-constrained reference and the oracle policy.
    oracle_cache = {}

    def _oracle(scen_key):
        if scen_key not in oracle_cache:
            oracle_cache[scen_key] = optimal_partition(db, list(scen_key),
                                                       num_eps)
        return oracle_cache[scen_key]

    def oracle_solver(cfg, src) -> List[int]:
        return list(_oracle(tuple(scenarios))[0])

    if isinstance(scheduler, str):
        sched_name = scheduler
        policy = make_scheduler(scheduler, alpha=alpha,
                                rel_threshold=rel_threshold,
                                solver=oracle_solver)
    else:
        policy = scheduler
        sched_name = getattr(policy, "name", type(policy).__name__)
    runtime = RebalanceRuntime(policy, config)

    latencies = np.zeros(num_queries)
    throughputs = np.zeros(num_queries)
    serial_mask = np.zeros(num_queries, dtype=bool)
    rc_thr = np.zeros(num_queries)
    configs_trace: List[List[int]] = []

    for q in range(num_queries):
        # -- advance interference state ------------------------------------
        active = {}
        for ev in events:
            if ev.start <= q < ev.end:
                active[ev.ep] = ev.scenario
        new_scen = [active.get(ep, 0) for ep in range(num_eps)]
        if new_scen != scenarios:
            scenarios[:] = new_scen
            source.scenarios[:] = new_scen
        rc_thr[q] = _oracle(tuple(scenarios))[1]

        # -- one runtime step: steady query, or one exploration trial -------
        step = runtime.poll(source)
        times = source.stage_times(step.config)
        latencies[q] = (serial_latency(times) if step.serial
                        else pipelined_latency(times))
        throughputs[q] = throughput(times)
        serial_mask[q] = step.serial
        configs_trace.append(list(step.config))

    return SimResult(
        scheduler=sched_name,
        latencies=latencies,
        throughputs=throughputs,
        serial_mask=serial_mask,
        peak_throughput=peak,
        rc_throughputs=rc_thr,
        num_rebalances=runtime.num_rebalances,
        total_trials=runtime.total_trials,
        configs_trace=configs_trace,
        mitigation_lengths=runtime.mitigation_lengths,
    )


# The paper's 9 frequency/duration settings (§4.2).
PAPER_SETTINGS = [(f, d) for f in (2, 10, 100) for d in (2, 10, 100)]
