"""Query-driven pipeline simulator (paper §4 methodology).

Simulates an inference pipeline of N stages bound to N execution places
serving a window of queries (paper: 4000).  Interference events start
every ``freq_period`` queries on a random EP with a random scenario from
the database and last ``duration`` queries (overlaps resolve to the
highest-severity scenario; :class:`~repro.core.events.EventTimeline`).
The scheduler under test is any registered :mod:`repro.schedulers`
policy (``odin`` / ``lls`` / ``oracle`` / ``none`` / ``hybrid`` / user
plugins); the per-query detect → explore → commit state machine is the
:class:`~repro.schedulers.runtime.RebalanceRuntime` and the per-query
tick itself is :func:`repro.workloads.run_pipeline` — both shared with
the live serving engine, so during a rebalancing phase queries are
processed serially — one query per trial — exactly the paper's
exploration-overhead accounting.

Traffic is pluggable (:mod:`repro.workloads`): the default ``closed``
workload reproduces the paper's saturated back-to-back stream
bit-for-bit; open-loop workloads (``poisson`` / ``bursty`` / ``trace``)
add arrival-queueing so latency decomposes into queueing delay +
service time.
"""
from __future__ import annotations

from typing import List, Optional, Union

import numpy as np

from repro.core.database import LayerDatabase
from repro.core.events import (  # noqa: F401  (re-exported, back-compat)
    EventTimeline,
    InterferenceEvent,
    generate_events,
)
from repro.core.exhaustive import optimal_partition
from repro.core.pipeline_state import (
    balanced_config,
    pipelined_latency,
    serial_latency,
    throughput,
)
from repro.schedulers.base import SchedulerPolicy
from repro.schedulers.registry import make_scheduler
from repro.schedulers.runtime import RebalanceRuntime, RuntimeStep
from repro.workloads import (
    BatchRecord,
    PipelineTrace,
    QueryRecord,
    Workload,
    run_pipeline,
)


class SimTimeSource:
    """StageTimeSource backed by the database + current per-EP scenarios."""

    def __init__(self, db: LayerDatabase, scenarios):
        self.db = db
        self.scenarios = list(scenarios)

    def stage_times(self, config) -> np.ndarray:
        return self.db.stage_times(config, self.scenarios)


#: Deprecated alias — the simulator now returns the unified
#: :class:`repro.workloads.PipelineTrace` (same fields plus the
#: arrival-queue surface).
SimResult = PipelineTrace


class DatabaseQueryExecutor:
    """Simulator-side :class:`~repro.workloads.QueryExecutor`.

    The environment advance is the interference-event timeline; query
    "execution" is a database lookup evaluated with the paper's latency
    model (pipelined when steady, serial during exploration trials).
    Provides the resource-constrained DP optimum as the trace's
    reference throughput.

    Batch-granular fast path: the scenario vector is piecewise-constant
    between interference-event edges, so a steady chunk needs exactly
    one database gather per (config, scenario-segment) —
    ``execute_many`` broadcasts it; ``steady_horizon`` is the distance
    to the next event edge.  ``batch_mode = "vector"``: chunking is a
    pure computational speedup, per-query semantics unchanged.

    ``time_indexed=True`` anchors the event windows on the arrival
    clock instead of the query index (open-loop runs only): the run
    loop announces the arrival times via :meth:`set_arrivals` before
    serving, and each query's environment is the scenario vector at its
    *arrival time* — how replica-scoped cluster events stay wall-clock
    aligned across replicas serving different query counts
    (docs/CLUSTER.md).
    """

    batch_mode = "vector"

    def __init__(self, db: LayerDatabase, num_eps: int,
                 events: List[InterferenceEvent], oracle,
                 time_indexed: bool = False):
        self.db = db
        self.num_eps = num_eps
        self.timeline = EventTimeline(events, num_eps,
                                      severity=db.scenario_severities(),
                                      time_indexed=time_indexed)
        self.scenarios = [0] * num_eps
        self.source = SimTimeSource(db, self.scenarios)
        self._oracle = oracle    # tuple(scenarios) -> (config, throughput)
        self._arrivals = None    # set by the run loop (time-indexed only)

    def set_arrivals(self, arrivals) -> None:
        """Run-loop hook: the per-query arrival times (``None`` for a
        closed loop).  Only consulted when the timeline is
        time-indexed, which requires an open-loop workload."""
        if self.timeline.time_indexed and arrivals is None:
            raise ValueError(
                "time-indexed interference events need an open-loop "
                "workload: a closed loop has no arrival clock to anchor "
                "the event windows on")
        self._arrivals = arrivals

    def _clock(self, q: int):
        """The timeline key for query ``q``: its arrival time on a
        time-indexed timeline, its index otherwise."""
        if not self.timeline.time_indexed:
            return q
        if self._arrivals is None:
            raise ValueError("time-indexed events: set_arrivals() was "
                             "never called with the arrival times")
        t = self._arrivals[q]
        if t is None:      # a closed-loop driver fed a clock of Nones
            raise ValueError(
                "time-indexed interference events need an open-loop "
                "workload: a closed loop has no arrival clock to anchor "
                "the event windows on")
        return t

    def begin_query(self, q: int) -> SimTimeSource:
        new_scen = self.timeline.scenarios_at(self._clock(q))
        if new_scen != self.scenarios:
            self.scenarios[:] = new_scen
            self.source.scenarios[:] = new_scen
        return self.source

    def steady_horizon(self, q: int) -> int:
        if not self.timeline.time_indexed:
            return self.timeline.next_change(q) - q
        # Queries arriving before the next event edge share q's
        # environment; the horizon is how many of them there are.
        edge = self.timeline.next_change(self._arrivals[q])
        if edge == float("inf"):
            return len(self._arrivals) - q
        return int(np.searchsorted(self._arrivals, edge, side="left")) - q

    def reference_throughput(self, q: int) -> float:
        return self._oracle(tuple(self.scenarios))[1]

    def execute(self, q: int, step: RuntimeStep) -> QueryRecord:
        times = self.source.stage_times(step.config)
        latency = (serial_latency(times) if step.serial
                   else pipelined_latency(times))
        return QueryRecord(service_latency=latency,
                           throughput=throughput(times))

    def execute_many(self, q0: int, steps) -> BatchRecord:
        # Steady chunks share one (config, scenario-segment): one
        # database gather serves every query in the chunk, broadcast
        # to the chunk without materializing per-query copies.
        times = self.source.stage_times(steps[0].config)
        n = len(steps)
        return BatchRecord(
            service_latencies=np.broadcast_to(pipelined_latency(times), n),
            throughputs=np.broadcast_to(throughput(times), n))


def simulate(db: LayerDatabase,
             num_eps: int,
             scheduler: Union[str, SchedulerPolicy] = "odin",
             alpha: int = 10,
             num_queries: int = 4000,
             freq_period: int = 10,
             duration: int = 10,
             seed: int = 0,
             rel_threshold: Optional[float] = None,
             events: Optional[List[InterferenceEvent]] = None,
             initial_config: Optional[List[int]] = None,
             workload: Union[str, Workload, None] = "closed",
             workload_kwargs: Optional[dict] = None,
             chunking: bool = True,
             max_chunk: Optional[int] = None,
             events_time_indexed: bool = False,
             admission: Union[str, object, None] = None,
             admission_kwargs: Optional[dict] = None,
             trace_mode: str = "dense",
             metrics_sink=None,
             sink_interval: Optional[int] = None) -> PipelineTrace:
    """Run one (scheduler, interference-setting, workload) simulation.

    ``scheduler`` is a registry name (``repro.schedulers``) or an
    already-constructed :class:`SchedulerPolicy` instance; ``workload``
    likewise resolves through :mod:`repro.workloads` (``closed`` —
    the default, the paper's saturated stream — or an open-loop
    process such as ``workload="poisson",
    workload_kwargs={"rate": ..., "seed": ...}``).
    ``rel_threshold=None`` uses the shared
    :data:`repro.schedulers.DEFAULT_REL_THRESHOLD`.

    ``chunking=False`` forces the scalar per-query tick (the fast path
    is the default; closed-loop traces are bit-identical either way —
    see docs/WORKLOADS.md "Batching & the fast path").

    ``events_time_indexed=True`` interprets ``events`` on the arrival
    clock instead of the query index (open-loop workloads only; events
    must then be supplied explicitly — ``generate_events`` produces
    query-indexed starts).

    ``admission`` selects a :mod:`repro.control` admission policy
    (e.g. ``admission="slo_shed", admission_kwargs={"slo": ...}``);
    shed queries are reported through the trace's shed/goodput
    surface.  The default (no policy) admits everything.

    ``trace_mode="streaming"`` / ``metrics_sink`` select the flat-memory
    telemetry path (docs/TELEMETRY.md): streaming runs return a
    :class:`~repro.telemetry.StreamingTrace` with the same ``summary()``
    keys, and a sink receives periodic metric snapshots in either mode.
    """
    if events is None:
        if events_time_indexed:
            raise ValueError("events_time_indexed=True needs explicit "
                             "events: generate_events() produces "
                             "query-indexed windows")
        events = generate_events(num_queries, num_eps, db.num_scenarios,
                                 freq_period, duration, seed)
    config = (list(initial_config) if initial_config is not None
              else balanced_config(db.num_layers, num_eps))
    # Interference-free peak throughput of the starting configuration:
    # by assumption (§3.1) the initial config is the balanced optimum.
    clean = SimTimeSource(db, [0] * num_eps)
    # Start from the true clean optimum so "peak" matches the paper's
    # "throughput of the inference pipeline when executing alone".
    if initial_config is None:
        opt_cfg, _ = optimal_partition(db, [0] * num_eps, num_eps)
        config = opt_cfg
    peak = throughput(clean.stage_times(config))

    # Cache the oracle per scenario-vector (it is deterministic); it backs
    # both the resource-constrained reference and the oracle policy.
    oracle_cache = {}

    def _oracle(scen_key):
        if scen_key not in oracle_cache:
            oracle_cache[scen_key] = optimal_partition(db, list(scen_key),
                                                       num_eps)
        return oracle_cache[scen_key]

    executor = DatabaseQueryExecutor(db, num_eps, events, _oracle,
                                     time_indexed=events_time_indexed)

    def oracle_solver(cfg, src) -> List[int]:
        return list(_oracle(tuple(executor.scenarios))[0])

    if isinstance(scheduler, str):
        sched_name = scheduler
        policy = make_scheduler(scheduler, alpha=alpha,
                                rel_threshold=rel_threshold,
                                solver=oracle_solver)
    else:
        policy = scheduler
        sched_name = getattr(policy, "name", type(policy).__name__)
    runtime = RebalanceRuntime(policy, config)

    return run_pipeline(executor, runtime, num_queries,
                        workload=workload, workload_kwargs=workload_kwargs,
                        scheduler_name=sched_name, peak_throughput=peak,
                        chunking=chunking, max_chunk=max_chunk,
                        admission=admission,
                        admission_kwargs=admission_kwargs,
                        trace_mode=trace_mode, metrics_sink=metrics_sink,
                        sink_interval=sink_interval)


# The paper's 9 frequency/duration settings (§4.2).
PAPER_SETTINGS = [(f, d) for f in (2, 10, 100) for d in (2, 10, 100)]
