"""Query-driven pipeline simulator (paper §4 methodology).

Simulates an inference pipeline of N stages bound to N execution places
serving a window of queries (paper: 4000).  Interference events start
every ``freq_period`` queries on a random EP with a random scenario from
the database and last ``duration`` queries.  The scheduler under test
(ODIN / LLS / oracle / none) observes only per-stage execution times;
during a rebalancing phase, queries are processed serially — one query
per trial — exactly the paper's exploration-overhead accounting.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.database import LayerDatabase
from repro.core.exhaustive import optimal_partition
from repro.core.lls import LLSController
from repro.core.odin import OdinController
from repro.core.pipeline_state import (
    balanced_config,
    pipelined_latency,
    serial_latency,
    throughput,
)


class SimTimeSource:
    """StageTimeSource backed by the database + current per-EP scenarios."""

    def __init__(self, db: LayerDatabase, scenarios: Sequence[int]):
        self.db = db
        self.scenarios = list(scenarios)

    def stage_times(self, config: Sequence[int]) -> np.ndarray:
        return self.db.stage_times(config, self.scenarios)


@dataclasses.dataclass
class InterferenceEvent:
    start: int      # query index at which the event begins
    duration: int   # in queries
    ep: int
    scenario: int   # column in the database (>= 1)

    @property
    def end(self) -> int:
        return self.start + self.duration


def generate_events(num_queries: int, num_eps: int, num_scenarios: int,
                    freq_period: int, duration: int,
                    seed: int = 0) -> List[InterferenceEvent]:
    """One event every ``freq_period`` queries on a random EP/scenario."""
    rng = np.random.default_rng(seed)
    events = []
    for start in range(freq_period, num_queries, freq_period):
        events.append(InterferenceEvent(
            start=start, duration=duration,
            ep=int(rng.integers(num_eps)),
            scenario=int(rng.integers(1, num_scenarios + 1))))
    return events


@dataclasses.dataclass
class SimResult:
    scheduler: str
    latencies: np.ndarray          # per query
    throughputs: np.ndarray        # per query (1 / bottleneck stage time)
    serial_mask: np.ndarray        # True where query was processed serially
    peak_throughput: float         # interference-free optimum
    rc_throughputs: np.ndarray     # resource-constrained optimum per query
    num_rebalances: int
    total_trials: int
    configs_trace: List[List[int]]
    mitigation_lengths: List[int]  # trials consumed per rebalancing phase

    @property
    def rebalance_fraction(self) -> float:
        return float(np.mean(self.serial_mask))

    @property
    def steady_throughput(self) -> float:
        """Mean throughput over pipelined (non-exploration) queries — the
        pipeline's operating rate, which is what the paper's Fig. 6
        reports (exploration overhead is Fig. 8's separate metric)."""
        pipe = self.throughputs[~self.serial_mask]
        return float(pipe.mean()) if len(pipe) else float(
            self.throughputs.mean())

    def tail_latency(self, pct: float = 99.0) -> float:
        return float(np.percentile(self.latencies, pct))

    def slo_violations(self, slo_level: float,
                       reference: str = "peak") -> float:
        """Fraction of queries with throughput below slo_level × reference."""
        if reference == "peak":
            target = slo_level * self.peak_throughput
            return float(np.mean(self.throughputs < target))
        elif reference == "resource_constrained":
            target = slo_level * self.rc_throughputs
            return float(np.mean(self.throughputs < target))
        raise ValueError(reference)


def _make_controller(scheduler: str, alpha: int, rel_threshold: float):
    if scheduler == "odin":
        return OdinController(alpha=alpha, rel_threshold=rel_threshold)
    if scheduler == "lls":
        return LLSController(rel_threshold=rel_threshold)
    if scheduler in ("none", "oracle"):
        return None
    raise ValueError(f"unknown scheduler {scheduler!r}")


def simulate(db: LayerDatabase,
             num_eps: int,
             scheduler: str = "odin",
             alpha: int = 10,
             num_queries: int = 4000,
             freq_period: int = 10,
             duration: int = 10,
             seed: int = 0,
             rel_threshold: float = 0.02,
             events: Optional[List[InterferenceEvent]] = None,
             initial_config: Optional[List[int]] = None) -> SimResult:
    """Run one (scheduler, interference-setting) simulation."""
    if events is None:
        events = generate_events(num_queries, num_eps, db.num_scenarios,
                                 freq_period, duration, seed)
    config = (list(initial_config) if initial_config is not None
              else balanced_config(db.num_layers, num_eps))
    # Interference-free peak throughput of the starting configuration:
    # by assumption (§3.1) the initial config is the balanced optimum.
    clean = SimTimeSource(db, [0] * num_eps)
    # Start from the true clean optimum so "peak" matches the paper's
    # "throughput of the inference pipeline when executing alone".
    if initial_config is None:
        opt_cfg, _ = optimal_partition(db, [0] * num_eps, num_eps)
        config = opt_cfg
    peak = throughput(clean.stage_times(config))

    controller = _make_controller(scheduler, alpha, rel_threshold)

    scenarios = [0] * num_eps
    source = SimTimeSource(db, scenarios)

    latencies = np.zeros(num_queries)
    throughputs = np.zeros(num_queries)
    serial_mask = np.zeros(num_queries, dtype=bool)
    rc_thr = np.zeros(num_queries)
    configs_trace: List[List[int]] = []
    mitigation_lengths: List[int] = []
    num_rebalances = 0
    total_trials = 0
    explorer = None  # in-progress rebalancing phase

    # Cache the oracle per scenario-vector (it is deterministic).
    oracle_cache = {}

    def rc_throughput() -> float:
        key = tuple(scenarios)
        if key not in oracle_cache:
            oracle_cache[key] = optimal_partition(db, scenarios, num_eps)
        return oracle_cache[key][1]

    for q in range(num_queries):
        # -- advance interference state ------------------------------------
        active = {}
        for ev in events:
            if ev.start <= q < ev.end:
                active[ev.ep] = ev.scenario
        new_scen = [active.get(ep, 0) for ep in range(num_eps)]
        if new_scen != scenarios:
            scenarios[:] = new_scen
            source.scenarios[:] = new_scen
        rc = rc_throughput()
        rc_thr[q] = rc

        # -- in-progress rebalancing phase: one trial = one serial query ----
        if explorer is not None:
            trial_cfg = explorer.step(source)
            times = source.stage_times(trial_cfg)
            latencies[q] = serial_latency(times)
            throughputs[q] = throughput(times)
            serial_mask[q] = True
            configs_trace.append(list(trial_cfg))
            if explorer.done:
                res = explorer.result()
                config = res.config
                total_trials += res.num_trials
                mitigation_lengths.append(res.num_trials)
                controller.finish(config, source)
                explorer = None
            continue

        # -- scheduler observation ------------------------------------------
        if scheduler == "oracle":
            opt_cfg, _ = oracle_cache[tuple(scenarios)]
            config = list(opt_cfg)
        elif controller is not None and controller.detect(config, source):
            num_rebalances += 1
            explorer = controller.make_explorer(config)
            trial_cfg = explorer.step(source)
            times = source.stage_times(trial_cfg)
            latencies[q] = serial_latency(times)
            throughputs[q] = throughput(times)
            serial_mask[q] = True
            configs_trace.append(list(trial_cfg))
            if explorer.done:
                res = explorer.result()
                config = res.config
                total_trials += res.num_trials
                mitigation_lengths.append(res.num_trials)
                controller.finish(config, source)
                explorer = None
            continue

        # -- steady-state pipelined query ------------------------------------
        times = source.stage_times(config)
        latencies[q] = pipelined_latency(times)
        throughputs[q] = throughput(times)
        configs_trace.append(list(config))

    return SimResult(
        scheduler=scheduler,
        latencies=latencies,
        throughputs=throughputs,
        serial_mask=serial_mask,
        peak_throughput=peak,
        rc_throughputs=rc_thr,
        num_rebalances=num_rebalances,
        total_trials=total_trials,
        configs_trace=configs_trace,
        mitigation_lengths=mitigation_lengths,
    )


# The paper's 9 frequency/duration settings (§4.2).
PAPER_SETTINGS = [(f, d) for f in (2, 10, 100) for d in (2, 10, 100)]
