"""Query-driven pipeline simulator (paper §4 methodology).

Simulates an inference pipeline of N stages bound to N execution places
serving a window of queries (paper: 4000).  Interference events start
every ``freq_period`` queries on a random EP with a random scenario from
the database and last ``duration`` queries (overlaps resolve to the
highest-severity scenario; :class:`~repro.core.events.EventTimeline`).
The scheduler under test is any registered :mod:`repro.schedulers`
policy (``odin`` / ``lls`` / ``oracle`` / ``none`` / ``hybrid`` / user
plugins); the per-query detect → explore → commit state machine is the
:class:`~repro.schedulers.runtime.RebalanceRuntime` and the per-query
tick itself is :func:`repro.workloads.run_pipeline` — both shared with
the live serving engine, so during a rebalancing phase queries are
processed serially — one query per trial — exactly the paper's
exploration-overhead accounting.

Traffic is pluggable (:mod:`repro.workloads`): the default ``closed``
workload reproduces the paper's saturated back-to-back stream
bit-for-bit; open-loop workloads (``poisson`` / ``bursty`` / ``trace``)
add arrival-queueing so latency decomposes into queueing delay +
service time.
"""
from __future__ import annotations

from typing import List, Optional, Union

import numpy as np

from repro.core.database import LayerDatabase
from repro.core.events import (  # noqa: F401  (re-exported, back-compat)
    EventTimeline,
    InterferenceEvent,
    generate_events,
)
from repro.core.exhaustive import optimal_partition, optimal_partition_mesh
from repro.core.mesh import (
    MeshSpec,
    balanced_assignment,
    collective_frac as _mesh_coll_frac,
    mesh_stage_times,
    resolve_mesh,
)
from repro.core.pipeline_state import (
    balanced_config,
    pipelined_latency,
    serial_latency,
    throughput,
)
from repro.schedulers.base import SchedulerPolicy
from repro.schedulers.registry import make_scheduler
from repro.schedulers.runtime import RebalanceRuntime, RuntimeStep
from repro.workloads import (
    BatchRecord,
    PipelineTrace,
    QueryRecord,
    Workload,
)
from repro.workloads.runner import _run_pipeline_impl
from repro.workloads.base import DispatchRecord
from repro.workloads.batching import resolve_batching


class SimTimeSource:
    """StageTimeSource backed by the database + current per-EP scenarios.

    Mesh-aware (docs/SHARDING.md): when built with a
    :class:`~repro.core.mesh.MeshSpec`, ``stage_times(config,
    assignment)`` prices the sharded cost model — explorers pass trial
    assignments explicitly; single-argument calls (detectors, latency
    estimators) use the *committed* :attr:`assignment` the runtime
    syncs onto this source.  ``mesh=None`` (or no committed assignment
    yet) returns the unsharded compute times bit-identically.
    """

    def __init__(self, db: LayerDatabase, scenarios,
                 mesh: Optional[MeshSpec] = None):
        self.db = db
        self.scenarios = list(scenarios)
        self.mesh = mesh
        self.assignment = None       # committed slices; the runtime syncs
        self.coll_factor = 1.0       # mesh-event inflation (begin_query)
        self._layer_costs = (mesh.layer_costs(db.num_layers)
                             if mesh is not None else None)

    def stage_times(self, config, assignment=None) -> np.ndarray:
        compute = self.db.stage_times(config, self.scenarios)
        if self.mesh is None:
            return compute
        a = assignment if assignment is not None else self.assignment
        if a is None:
            return compute
        return mesh_stage_times(compute, config, a, self.mesh,
                                self.coll_factor,
                                layer_costs=self._layer_costs)

    def collective_frac(self, config, assignment=None) -> float:
        """Bottleneck stage's collective share (0.0 unsharded)."""
        if self.mesh is None:
            return 0.0
        a = assignment if assignment is not None else self.assignment
        if a is None:
            return 0.0
        compute = self.db.stage_times(config, self.scenarios)
        return _mesh_coll_frac(compute, config, a, self.mesh,
                               self.coll_factor,
                               layer_costs=self._layer_costs)


def _dispatch_throughput(spans: np.ndarray) -> float:
    """Throughput a dispatch record reports: one batch per full drain.

    Batched dispatch is group-synchronous — the engine launches the
    next dispatch only after this one retires — so the head occupancy
    (``1/throughput``) is the whole wall, not the bottleneck stage.
    Every dispatch site (profile, builder, execute, execute_many) goes
    through this one helper so the floats agree bit-for-bit.
    """
    total = float(np.sum(spans))
    return 1.0 / total if total > 0.0 else float("inf")


#: Deprecated alias — the simulator now returns the unified
#: :class:`repro.workloads.PipelineTrace` (same fields plus the
#: arrival-queue surface).
SimResult = PipelineTrace


class DatabaseQueryExecutor:
    """Simulator-side :class:`~repro.workloads.QueryExecutor`.

    The environment advance is the interference-event timeline; query
    "execution" is a database lookup evaluated with the paper's latency
    model (pipelined when steady, serial during exploration trials).
    Provides the resource-constrained DP optimum as the trace's
    reference throughput.

    Batch-granular fast path: the scenario vector is piecewise-constant
    between interference-event edges, so a steady chunk needs exactly
    one database gather per (config, scenario-segment) —
    ``execute_many`` broadcasts it; ``steady_horizon`` is the distance
    to the next event edge.  ``batch_mode = "vector"``: chunking is a
    pure computational speedup, per-query semantics unchanged.

    ``time_indexed=True`` anchors the event windows on the arrival
    clock instead of the query index (open-loop runs only): the run
    loop announces the arrival times via :meth:`set_arrivals` before
    serving, and each query's environment is the scenario vector at its
    *arrival time* — how replica-scoped cluster events stay wall-clock
    aligned across replicas serving different query counts
    (docs/CLUSTER.md).

    **Batched-dispatch cost model** (active only when a
    :class:`~repro.workloads.batching.BatchFormer` is attached via
    :meth:`configure_batching`; everything below is bypassed otherwise,
    keeping pre-batching runs bit-identical): a stage executing a
    member set ``M`` takes ``overhead + t_s * sum_i(Lpad_i /
    length_ref)`` — a fixed per-dispatch stage cost (kernel launch +
    sync) plus compute linear in padded tokens.  A solo query's service
    time is the sum over occupied stages, and so is its head occupancy:
    batched dispatch is group-synchronous — the next dispatch launches
    only after this one drains, which is exactly why continuous joins
    pay (the steady-state ``pipelined_latency`` model keeps governing
    non-batched runs).
    """

    batch_mode = "vector"

    def __init__(self, db: LayerDatabase, num_eps: int,
                 events: List[InterferenceEvent], oracle,
                 time_indexed: bool = False,
                 mesh: Optional[MeshSpec] = None):
        self.db = db
        self.num_eps = num_eps
        self.mesh = mesh
        self.timeline = EventTimeline(events, num_eps,
                                      severity=db.scenario_severities(),
                                      time_indexed=time_indexed)
        self.scenarios = [0] * num_eps
        self.source = SimTimeSource(db, self.scenarios, mesh=mesh)
        self._oracle = oracle    # tuple(scenarios) -> (config, throughput)
        self._arrivals = None    # set by the run loop (time-indexed only)
        self.former = None       # BatchFormer (configure_batching)
        self._lengths = None     # per-query actual lengths
        self._padded = None      # per-query bucket-padded lengths
        self.batch_overhead = 0.0
        self.length_ref = None   # resolved at configure_batching time

    # -- batched dispatch (opt-in) ------------------------------------------
    def set_cost_model(self, batch_overhead: float,
                       length_ref: Optional[float] = None) -> None:
        """Tune the dispatch cost model (see class docs): fixed
        per-stage dispatch overhead, and the sequence length the
        database's profiled times correspond to (``None`` = derive from
        the run's largest padded length at :meth:`configure_batching`
        time)."""
        self.batch_overhead = float(batch_overhead)
        if length_ref is not None and length_ref <= 0:
            raise ValueError(f"length_ref must be > 0, got {length_ref}")
        self.length_ref = None if length_ref is None else float(length_ref)

    def configure_batching(self, former, lengths, padded) -> None:
        """Run-loop hook: attach the batch former + per-query lengths
        (actual and bucket-padded) before serving begins."""
        self.former = former
        self._lengths = lengths
        self._padded = padded
        if self.length_ref is None:
            self.length_ref = (float(np.max(padded))
                               if padded is not None else 1.0)

    def _lfrac(self, q: int) -> float:
        """Padded-length compute fraction of query ``q`` vs. the
        reference length the database times were profiled at."""
        if self._padded is None:
            return 1.0
        return float(self._padded[q]) / self.length_ref

    def _dispatch_times(self, config, lfrac: float,
                        assignment=None) -> np.ndarray:
        """Per-stage solo dispatch times under the batching cost model.
        On sharded runs the stage times already carry the committed (or
        explicitly passed trial) assignment's cost model."""
        times = self.source.stage_times(config, assignment)
        return np.where(times > 0.0,
                        self.batch_overhead + times * lfrac, 0.0)

    def dispatch_profile(self, q: int, config) -> tuple:
        """(wall, throughput, last_join_offset) of a solo dispatch of ``q``.

        ``throughput`` goes through the same helper a size-1 dispatch
        record reports, so the run loop's predicted head occupancy
        (``1/throughput``) is bit-identical to the ledger advance the
        executed dispatch will make.  ``last_join_offset`` is the clock
        offset of the final stage boundary a continuous joiner could
        still enter at — the vectorized path proves a stretch join-free
        by checking successor arrivals against it.
        """
        tp = self._dispatch_times(config, self._lfrac(q))
        wall = float(np.sum(tp))
        join = float(np.sum(tp[:-1])) if len(tp) > 1 else 0.0
        return wall, _dispatch_throughput(tp), join

    def begin_dispatch(self, q0: int, step: RuntimeStep):
        """Start forming a dispatch headed by query ``q0``."""
        return _SimDispatchBuilder(self, step.config, step.mesh)

    def set_arrivals(self, arrivals) -> None:
        """Run-loop hook: the per-query arrival times (``None`` for a
        closed loop).  Only consulted when the timeline is
        time-indexed, which requires an open-loop workload."""
        if self.timeline.time_indexed and arrivals is None:
            raise ValueError(
                "time-indexed interference events need an open-loop "
                "workload: a closed loop has no arrival clock to anchor "
                "the event windows on")
        self._arrivals = arrivals

    def _clock(self, q: int):
        """The timeline key for query ``q``: its arrival time on a
        time-indexed timeline, its index otherwise."""
        if not self.timeline.time_indexed:
            return q
        if self._arrivals is None:
            raise ValueError("time-indexed events: set_arrivals() was "
                             "never called with the arrival times")
        t = self._arrivals[q]
        if t is None:      # a closed-loop driver fed a clock of Nones
            raise ValueError(
                "time-indexed interference events need an open-loop "
                "workload: a closed loop has no arrival clock to anchor "
                "the event windows on")
        return t

    def begin_query(self, q: int) -> SimTimeSource:
        clock = self._clock(q)
        new_scen = self.timeline.scenarios_at(clock)
        if new_scen != self.scenarios:
            self.scenarios[:] = new_scen
            self.source.scenarios[:] = new_scen
        if self.mesh is not None:
            self.source.coll_factor = self.timeline.coll_factor_at(clock)
        return self.source

    def steady_horizon(self, q: int) -> int:
        if not self.timeline.time_indexed:
            return self.timeline.next_change(q) - q
        # Queries arriving before the next event edge share q's
        # environment; the horizon is how many of them there are.
        edge = self.timeline.next_change(self._arrivals[q])
        if edge == float("inf"):
            return len(self._arrivals) - q
        return int(np.searchsorted(self._arrivals, edge, side="left")) - q

    def reference_throughput(self, q: int) -> float:
        return self._oracle(tuple(self.scenarios))[1]

    def execute(self, q: int, step: RuntimeStep) -> QueryRecord:
        if self.former is not None:
            # Dispatch cost model: a solo query traverses its own
            # dispatch — sum of per-stage costs; dispatches are
            # group-synchronous, so the head is held for the full
            # drain.  Serial trials traverse the same stages (the
            # drain wait is the run loop's business).
            tp = self._dispatch_times(step.config, self._lfrac(q),
                                      step.mesh)
            cf = (self.source.collective_frac(step.config, step.mesh)
                  if self.mesh is not None else 0.0)
            return QueryRecord(service_latency=float(np.sum(tp)),
                               throughput=_dispatch_throughput(tp),
                               collective_frac=cf)
        times = self.source.stage_times(step.config, step.mesh)
        latency = (serial_latency(times) if step.serial
                   else pipelined_latency(times))
        cf = (self.source.collective_frac(step.config, step.mesh)
              if self.mesh is not None else 0.0)
        return QueryRecord(service_latency=latency,
                           throughput=throughput(times),
                           collective_frac=cf)

    def execute_many(self, q0: int, steps) -> BatchRecord:
        # Steady chunks share one (config, scenario-segment): one
        # database gather serves every query in the chunk, broadcast
        # to the chunk without materializing per-query copies.
        n = len(steps)
        cfs = None
        if self.mesh is not None:
            cfs = np.broadcast_to(
                self.source.collective_frac(steps[0].config,
                                            steps[0].mesh), n)
        if self.former is not None:
            # Chunks under a former are join-free solo stretches at one
            # padded length (the run loop cuts at bucket changes and
            # join points), so one dispatch profile broadcasts — the
            # identical floats a size-1 dispatch builder would report.
            tp = self._dispatch_times(steps[0].config, self._lfrac(q0),
                                      steps[0].mesh)
            return BatchRecord(
                service_latencies=np.broadcast_to(float(np.sum(tp)), n),
                throughputs=np.broadcast_to(_dispatch_throughput(tp), n),
                collective_fracs=cfs)
        times = self.source.stage_times(steps[0].config, steps[0].mesh)
        return BatchRecord(
            service_latencies=np.broadcast_to(pipelined_latency(times), n),
            throughputs=np.broadcast_to(throughput(times), n),
            collective_fracs=cfs)


class _SimDispatchBuilder:
    """Analytic dispatch builder (``begin_dispatch`` protocol).

    Tracks every span the dispatch executes — per-stage batch times
    plus joiners' catch-up runs — as a list; ``drain`` is their sum and
    the head is held for the largest one.  All reductions go through
    the same numpy calls ``execute``/``execute_many`` use, so a size-1
    dispatch is bit-identical to the vectorized solo-stretch path (the
    chunked == scalar invariant extends to batched runs).
    """

    def __init__(self, ex: "DatabaseQueryExecutor", config,
                 assignment=None):
        self._ex = ex
        self._times = ex.source.stage_times(config, assignment)
        self._coll_frac = (ex.source.collective_frac(config, assignment)
                           if ex.mesh is not None else 0.0)
        self._live = self._times > 0.0
        self._c = ex.batch_overhead
        self._S = len(self._times)
        self._stage = 0
        self._spans: List[float] = []
        self._starts: List[float] = []
        self._sum_lfrac = 0.0
        self._padded_tok = 0.0
        self._actual_tok = 0.0
        self._row_lfrac: Optional[float] = None   # head bucket, set on add
        self._row_pad: Optional[float] = None

    def _count_tokens(self, q: int) -> None:
        ex = self._ex
        if ex._padded is not None:
            # Rows occupy the dispatch width (the head's bucket) —
            # formation members share it, joiners pad up to it.
            self._padded_tok += (self._row_pad
                                 if self._row_pad is not None
                                 else float(ex._padded[q]))
            actual = ex._lengths[q] if ex._lengths is not None \
                else ex._padded[q]
            self._actual_tok += float(actual)

    def _clock(self) -> float:
        if not self._spans:
            return 0.0
        return float(np.sum(np.asarray(self._spans)))

    def add(self, q: int) -> None:
        if self._stage != 0:
            raise RuntimeError("add() after launch; use join()")
        if self._row_lfrac is None:
            self._row_lfrac = self._ex._lfrac(q)
            if self._ex._padded is not None:
                self._row_pad = float(self._ex._padded[q])
        self._sum_lfrac += self._row_lfrac
        self._starts.append(0.0)
        self._count_tokens(q)

    def next_boundary(self) -> Optional[float]:
        if self._stage >= self._S:
            return None
        s = self._stage
        T = (self._c + float(self._times[s]) * self._sum_lfrac
             if self._live[s] else 0.0)
        self._spans.append(T)
        self._stage += 1
        if self._stage >= self._S:
            return None      # drained: nothing left to join
        return self._clock()

    def join(self, q: int) -> None:
        if not 0 < self._stage < self._S:
            raise RuntimeError("join() is only valid at a stage boundary")
        lf = self._ex._lfrac(q)
        # Service begins at the boundary; the batch then waits out the
        # joiner's solo catch-up through the already-executed stages —
        # one fused ``run_stages(0, s)`` launch (a single dispatch
        # overhead), compute linear in the joiner's padded tokens.
        self._starts.append(self._clock())
        done = self._live[:self._stage]
        comp = float(np.sum(np.where(
            done, self._times[:self._stage] * lf, 0.0)))
        if bool(np.any(done)):
            self._spans.append(self._c + comp)
        self._sum_lfrac += lf
        self._count_tokens(q)

    def finish(self) -> DispatchRecord:
        while self._stage < self._S:
            self.next_boundary()
        spans = np.asarray(self._spans, float)
        return DispatchRecord(start_offsets=np.asarray(self._starts),
                              drain=float(np.sum(spans)),
                              throughput=_dispatch_throughput(spans),
                              padded_tokens=self._padded_tok,
                              actual_tokens=self._actual_tok,
                              collective_frac=self._coll_frac)


def _simulate_impl(db: LayerDatabase,
             num_eps: int,
             scheduler: Union[str, SchedulerPolicy] = "odin",
             alpha: int = 10,
             num_queries: int = 4000,
             freq_period: int = 10,
             duration: int = 10,
             seed: int = 0,
             rel_threshold: Optional[float] = None,
             events: Optional[List[InterferenceEvent]] = None,
             initial_config: Optional[List[int]] = None,
             workload: Union[str, Workload, None] = "closed",
             workload_kwargs: Optional[dict] = None,
             chunking: bool = True,
             max_chunk: Optional[int] = None,
             events_time_indexed: bool = False,
             admission: Union[str, object, None] = None,
             admission_kwargs: Optional[dict] = None,
             trace_mode: str = "dense",
             metrics_sink=None,
             sink_interval: Optional[int] = None,
             batching=None,
             max_batch: int = 8,
             buckets=None,
             explore_in_batch: bool = False,
             lengths=None,
             lengths_kwargs: Optional[dict] = None,
             batch_overhead: float = 0.0,
             length_ref: Optional[float] = None,
             faults=None,
             retries=None,
             tiers=None,
             tiers_kwargs: Optional[dict] = None,
             mesh=None) -> PipelineTrace:
    """Run one (scheduler, interference-setting, workload) simulation.

    ``scheduler`` is a registry name (``repro.schedulers``) or an
    already-constructed :class:`SchedulerPolicy` instance; ``workload``
    likewise resolves through :mod:`repro.workloads` (``closed`` —
    the default, the paper's saturated stream — or an open-loop
    process such as ``workload="poisson",
    workload_kwargs={"rate": ..., "seed": ...}``).
    ``rel_threshold=None`` uses the shared
    :data:`repro.schedulers.DEFAULT_REL_THRESHOLD`.

    ``chunking=False`` forces the scalar per-query tick (the fast path
    is the default; closed-loop traces are bit-identical either way —
    see docs/WORKLOADS.md "Batching & the fast path").

    ``events_time_indexed=True`` interprets ``events`` on the arrival
    clock instead of the query index (open-loop workloads only; events
    must then be supplied explicitly — ``generate_events`` produces
    query-indexed starts).

    ``admission`` selects a :mod:`repro.control` admission policy
    (e.g. ``admission="slo_shed", admission_kwargs={"slo": ...}``);
    shed queries are reported through the trace's shed/goodput
    surface.  The default (no policy) admits everything.

    ``trace_mode="streaming"`` / ``metrics_sink`` select the flat-memory
    telemetry path (docs/TELEMETRY.md): streaming runs return a
    :class:`~repro.telemetry.StreamingTrace` with the same ``summary()``
    keys, and a sink receives periodic metric snapshots in either mode.

    ``batching`` turns on formed dispatch (docs/WORKLOADS.md
    "Continuous batching & length buckets"): ``"drain"`` stacks queued
    arrivals at dispatch instants, ``"continuous"`` additionally folds
    them in at stage boundaries; ``max_batch`` / ``buckets`` /
    ``explore_in_batch`` parameterize the
    :class:`~repro.workloads.batching.BatchFormer`.  ``lengths``
    attaches a per-query sequence-length distribution
    (:mod:`repro.workloads.lengths`); ``batch_overhead`` is the fixed
    per-stage dispatch cost and ``length_ref`` the sequence length the
    database times were profiled at (defaults to the largest bucket
    edge, else the largest sampled length).  ``batching=None`` (the
    default) bypasses all of it — bit-identical to pre-batching runs.

    ``faults`` injects deterministic failures (docs/FAULTS.md): a
    :class:`~repro.faults.FaultPlan`, a spec string such as
    ``"crash@100+50"``, or a list of either; ``retries`` configures the
    transient-failure retry budget (``RetrySpec``, int, or dict).
    ``faults=None`` leaves every trace bit-identical to a fault-free
    build.

    ``mesh`` shards every stage over a slice of a device mesh
    (docs/SHARDING.md): a :class:`~repro.core.mesh.MeshSpec`, a device
    count, or a kwargs dict (``{"devices": 8, "coll_cost": ...}``).
    Stage times follow the sharded cost model, the rebalance action
    space grows to (boundary, slice) moves, ``kind="mesh"`` events
    inflate collective time, and the trace gains the mesh surface
    (``mesh_trace`` / ``collective_fracs`` / mesh summary keys).
    ``mesh=None`` (the default) is bit-identical to an unsharded build.

    ``tiers`` stamps every arrival with a QoS tier (docs/QOS.md): a
    :class:`~repro.qos.TierAssigner`, pre-built
    :class:`~repro.qos.TierPlan`, preset-name string such as
    ``"interactive,best_effort"``, or a sequence of tier specs
    (``tiers_kwargs`` feeds the assignment mixture/seed).  Tiered
    traces grow per-tier accounting; ``tiers=None`` (the default)
    leaves every trace bit-identical to an untier-ed build.
    """
    if events is None:
        if events_time_indexed:
            raise ValueError("events_time_indexed=True needs explicit "
                             "events: generate_events() produces "
                             "query-indexed windows")
        events = generate_events(num_queries, num_eps, db.num_scenarios,
                                 freq_period, duration, seed)
    mesh_spec = resolve_mesh(mesh)
    config = (list(initial_config) if initial_config is not None
              else balanced_config(db.num_layers, num_eps))
    # Interference-free peak throughput of the starting configuration:
    # by assumption (§3.1) the initial config is the balanced optimum.
    clean = SimTimeSource(db, [0] * num_eps, mesh=mesh_spec)
    # Start from the true clean optimum so "peak" matches the paper's
    # "throughput of the inference pipeline when executing alone".
    if mesh_spec is None:
        if initial_config is None:
            opt_cfg, _ = optimal_partition(db, [0] * num_eps, num_eps)
            config = opt_cfg
        init_assign = None
    else:
        init_assign = balanced_assignment(mesh_spec.devices, num_eps)
        if initial_config is None:
            opt_cfg, opt_assign, _ = optimal_partition_mesh(
                db, [0] * num_eps, num_eps, mesh_spec)
            config, init_assign = list(opt_cfg), list(opt_assign)
        clean.assignment = list(init_assign)
    peak = throughput(clean.stage_times(config))

    # Cache the oracle per scenario-vector (it is deterministic); it backs
    # both the resource-constrained reference and the oracle policy.  On
    # sharded runs the key also carries the live collective-contention
    # factor and the value's first element is a (config, assignment) pair.
    oracle_cache = {}

    def _oracle(scen_key):
        if mesh_spec is None:
            if scen_key not in oracle_cache:
                oracle_cache[scen_key] = optimal_partition(
                    db, list(scen_key), num_eps)
            return oracle_cache[scen_key]
        f = executor.source.coll_factor
        key = (scen_key, f)
        if key not in oracle_cache:
            cfg, assign, T = optimal_partition_mesh(
                db, list(scen_key), num_eps, mesh_spec, coll_factor=f)
            oracle_cache[key] = ((cfg, assign), T)
        return oracle_cache[key]

    executor = DatabaseQueryExecutor(db, num_eps, events, _oracle,
                                     time_indexed=events_time_indexed,
                                     mesh=mesh_spec)
    former = resolve_batching(batching, max_batch=max_batch,
                              buckets=buckets,
                              explore_in_batch=explore_in_batch)
    if length_ref is None and former is not None \
            and former.buckets is not None:
        length_ref = float(former.buckets.edges[-1])
    executor.set_cost_model(batch_overhead, length_ref)

    def oracle_solver(cfg, src):
        opt = _oracle(tuple(executor.scenarios))[0]
        if mesh_spec is not None:
            return (list(opt[0]), list(opt[1]))
        return list(opt)

    if isinstance(scheduler, str):
        sched_name = scheduler
        policy = make_scheduler(scheduler, alpha=alpha,
                                rel_threshold=rel_threshold,
                                solver=oracle_solver)
    else:
        policy = scheduler
        sched_name = getattr(policy, "name", type(policy).__name__)
    runtime = RebalanceRuntime(policy, config, mesh=init_assign)

    return _run_pipeline_impl(
        executor, runtime, num_queries,
        workload=workload, workload_kwargs=workload_kwargs,
        scheduler_name=sched_name, peak_throughput=peak,
        chunking=chunking, max_chunk=max_chunk,
        admission=admission,
        admission_kwargs=admission_kwargs,
        trace_mode=trace_mode, metrics_sink=metrics_sink,
        sink_interval=sink_interval,
        former=former, lengths=lengths,
        lengths_kwargs=lengths_kwargs,
        faults=faults, retries=retries,
        tiers=tiers, tiers_kwargs=tiers_kwargs)


def simulate(db: LayerDatabase,
             num_eps: int,
             scheduler: Union[str, SchedulerPolicy] = "odin",
             alpha: int = 10,
             num_queries: int = 4000,
             freq_period: int = 10,
             duration: int = 10,
             seed: int = 0,
             rel_threshold: Optional[float] = None,
             events: Optional[List[InterferenceEvent]] = None,
             initial_config: Optional[List[int]] = None,
             workload: Union[str, Workload, None] = "closed",
             workload_kwargs: Optional[dict] = None,
             chunking: bool = True,
             max_chunk: Optional[int] = None,
             events_time_indexed: bool = False,
             admission: Union[str, object, None] = None,
             admission_kwargs: Optional[dict] = None,
             trace_mode: str = "dense",
             metrics_sink=None,
             sink_interval: Optional[int] = None,
             batching=None,
             max_batch: int = 8,
             buckets=None,
             explore_in_batch: bool = False,
             lengths=None,
             lengths_kwargs: Optional[dict] = None,
             batch_overhead: float = 0.0,
             length_ref: Optional[float] = None,
             faults=None,
             retries=None,
             tiers=None,
             tiers_kwargs: Optional[dict] = None) -> PipelineTrace:
    """Run one (scheduler, interference-setting, workload) simulation.

    Thin wrapper over the unified :class:`repro.api.RunSpec` path (one
    declaration, one dispatcher — docs/API.md); the kwargs here map
    1:1 onto spec fields, traces are bit-identical either way, and
    *new* options land on the spec instead of this signature — e.g.
    mesh-sliced stages (docs/SHARDING.md) are
    ``run(RunSpec(db=db, ..., mesh=MeshSpec(...)))`` only.  See
    :func:`_simulate_impl` for the full kwarg-level documentation.
    """
    from repro import api
    spec = api.RunSpec(
        db=db, num_eps=num_eps, num_queries=num_queries,
        freq_period=freq_period, duration=duration, seed=seed,
        events=events, events_time_indexed=events_time_indexed,
        scheduler=api.SchedulerSpec(name=scheduler, alpha=alpha,
                                    rel_threshold=rel_threshold,
                                    initial_config=initial_config),
        workload=api.WorkloadSpec(name=workload, kwargs=workload_kwargs),
        admission=api.AdmissionSpec(name=admission,
                                    kwargs=admission_kwargs),
        batching=api.BatchingSpec(mode=batching, max_batch=max_batch,
                                  buckets=buckets,
                                  explore_in_batch=explore_in_batch,
                                  chunking=chunking, max_chunk=max_chunk,
                                  lengths=lengths,
                                  lengths_kwargs=lengths_kwargs,
                                  batch_overhead=batch_overhead,
                                  length_ref=length_ref),
        faults=api.FaultsSpec(plan=faults),
        retries=api.RetriesSpec(policy=retries),
        tiers=api.TiersSpec(spec=tiers, kwargs=tiers_kwargs),
        telemetry=api.TelemetrySpec(trace_mode=trace_mode,
                                    metrics_sink=metrics_sink,
                                    sink_interval=sink_interval))
    return api.run(spec)


# The paper's 9 frequency/duration settings (§4.2).
PAPER_SETTINGS = [(f, d) for f in (2, 10, 100) for d in (2, 10, 100)]
