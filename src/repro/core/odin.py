"""ODIN heuristic pipeline-stage rebalancing (paper Algorithm 1).

Faithful transcription, with the two paper heuristics:

1. *Set the direction for moving work* — the first trial sheds one layer
   from both ends of the affected (slowest) stage; the direction is the
   side with the smaller total stage time; the receiving stage is the
   lightest on that side.
2. *Avoiding local optimum* — on a throughput plateau (T_new == T), move
   an extra layer from the affected stage to the lightest stage.

The patience counter ``γ`` bounds consecutive non-improving trials by the
tuning parameter ``α``; on improvement ``γ`` resets and the best-seen
configuration is recorded.

The algorithm is *online*: each loop iteration is one serially-processed
query (paper §4.2, "Exploration overhead": ~4 trials for α=2, ~12 for
α=10).  :class:`OdinExplorer` exposes exactly one iteration per
``step()`` so the simulator (and the live JAX serving loop) can interleave
trials with the evolving interference state; :func:`odin_rebalance` is the
run-to-completion convenience wrapper against a frozen state.

Edge-case policy (the paper's pseudocode leaves these implicit):

* moves that would make a stage count non-positive are skipped; a stage
  reaching 0 layers shortens the pipeline ("removing layers from the
  affected PS may reduce the length of the pipeline by 1") — empty stages
  are skipped when locating the bottleneck and are natural receivers when
  reclaiming resources (§3.1).
* at the pipeline ends only the existing neighbour receives a layer.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.pipeline_state import StageTimeSource, throughput


@dataclasses.dataclass
class Trial:
    config: List[int]
    throughput: float
    improved: bool
    #: Mesh assignment the trial ran with (``None`` = unsharded run).
    mesh: Optional[List[int]] = None


@dataclasses.dataclass
class RebalanceResult:
    config: List[int]
    throughput: float
    trials: List[Trial]
    #: Best-seen mesh assignment (``None`` = unsharded run).
    mesh: Optional[List[int]] = None

    @property
    def num_trials(self) -> int:
        return len(self.trials)


def _nonempty(config: Sequence[int]) -> List[int]:
    return [i for i, c in enumerate(config) if c > 0]


def _affected_index(times: np.ndarray, config: Sequence[int]) -> int:
    """Slowest *non-empty* stage."""
    idx = _nonempty(config)
    return max(idx, key=lambda i: times[i])


def _lightest_in_direction(times: np.ndarray, config: Sequence[int],
                           affected: int, direction: str) -> Optional[int]:
    """Lightest stage strictly on one side of the affected stage.

    Empty stages count as weight 0 — the natural receivers when the
    pipeline previously shrank (resource reclaim, §3.1).
    """
    cand = list(range(0, affected)) if direction == "left" else \
        list(range(affected + 1, len(config)))
    if not cand:
        return None
    return min(cand, key=lambda i: times[i])


class OdinExplorer:
    """One Algorithm-1 iteration per ``step()`` (one serial query each)."""

    serial = True   # each step costs one serially-processed query

    def __init__(self, config: Sequence[int], alpha: int):
        self.C = list(config)
        self.alpha = alpha
        self.gamma = 0
        self.T: Optional[float] = None       # best-so-far throughput
        self.C_opt = list(config)
        self.trials: List[Trial] = []
        self.done = False

    # -- internals -----------------------------------------------------------
    def _move(self, src: int, dst: int) -> bool:
        """Move one layer src -> dst; False if src cannot donate."""
        if self.C[src] <= 1:
            return False
        self.C[src] -= 1
        self.C[dst] += 1
        return True

    def step(self, source: StageTimeSource) -> List[int]:
        """Run one exploration iteration; returns the trial configuration
        the (serial) query is processed with."""
        assert not self.done
        C = self.C
        n = len(C)
        # Refresh the reference throughput against *live* stage times of
        # the best-seen configuration: the algorithm is online and the
        # interference state may change mid-phase — comparing trials to a
        # stale baseline would reject every move after conditions worsen
        # (and the phase would return the original, now-degraded config).
        self.T = throughput(source.stage_times(self.C_opt))

        times = source.stage_times(C)
        affected = _affected_index(times, C)

        if self.gamma == 0 and not self.trials:
            # First trial: shed one layer from both ends of PS_affected
            # (Lines 6-10).
            take = 0
            if affected + 1 < n and C[affected] > take + 1:
                C[affected + 1] += 1
                take += 1
            if affected - 1 >= 0 and C[affected] > take + 1:
                C[affected - 1] += 1
                take += 1
            C[affected] -= take
            times = source.stage_times(C)
            affected = _affected_index(times, C)

        # Direction: side with the smaller total time (Lines 11-17).
        s_left = float(np.sum(times[:affected]))
        s_right = float(np.sum(times[affected + 1:]))
        direction = "left" if s_left < s_right else "right"
        lightest = _lightest_in_direction(times, C, affected, direction)
        if lightest is None:
            direction = "left" if direction == "right" else "right"
            lightest = _lightest_in_direction(times, C, affected, direction)
        if lightest is None:
            # Single-stage pipeline: nothing to move, exploration is done.
            self.done = True
            self.C_opt = list(C)
            return list(C)

        if not self._move(affected, lightest):
            # Affected stage holds a single layer and cannot donate: the
            # configuration is unchanged, so re-measuring it would record
            # a duplicate-config trial as a fresh measurement.  Count a
            # non-improving step (so patience still terminates the phase)
            # without emitting a trial.
            self.gamma += 1
            if self.gamma >= self.alpha:
                self.done = True
            return list(C)
        T_new = throughput(source.stage_times(C))

        if T_new < self.T:
            self.gamma += 1
            self.trials.append(Trial(list(C), T_new, False))
        elif T_new == self.T:
            # Local-optimum escape (Lines 24-27): one extra layer.
            if self._move(affected, lightest):
                T_new = throughput(source.stage_times(C))
                self.gamma += 1
                improved = T_new > self.T
                if improved:
                    self.T = T_new
                    self.C_opt = list(C)
                    self.gamma = 0
                self.trials.append(Trial(list(C), T_new, improved))
            else:
                # Escape move failed (donor down to 1 layer): keep the
                # already-measured single-move trial instead of recording
                # the same configuration again as a fresh measurement.
                self.gamma += 1
                self.trials.append(Trial(list(C), T_new, False))
        else:
            self.gamma = 0
            self.T = T_new
            self.C_opt = list(C)
            self.trials.append(Trial(list(C), T_new, True))

        if self.gamma >= self.alpha:
            self.done = True
        return list(C)

    def result(self) -> RebalanceResult:
        return RebalanceResult(list(self.C_opt), float(self.T or 0.0),
                               list(self.trials))


class MeshOdinExplorer(OdinExplorer):
    """Algorithm 1 over the (boundary, slice) action space
    (docs/SHARDING.md).

    Each ``step()`` still costs one serially-processed query, but the
    move set grows: besides shifting one layer off the affected
    (slowest) stage, a trial may shift one *device* into it from an
    adjacent stage's mesh slice (adjacent-only shifts keep every
    stage's device range contiguous).  Candidates are ranked with the
    same stage-time source the trial is measured against — in the
    simulator prediction and measurement coincide, live the EMA
    estimates fill the role, exactly as for layer moves.  Patience
    (``γ``/``α``), plateau escape and best-seen tracking follow the
    parent; the unsharded explorer is bit-untouched (this class is only
    constructed when a mesh is armed).
    """

    def __init__(self, config: Sequence[int], alpha: int,
                 mesh: Sequence[int]):
        super().__init__(config, alpha)
        self.A = list(mesh)
        self.A_opt = list(mesh)

    # -- candidate enumeration ------------------------------------------------
    def _candidates(self, times: np.ndarray, affected: int):
        """(config, assignment) single moves off/into the affected
        stage, deterministic order: layer move first, then device
        shifts from the left / right neighbour."""
        C, A, n = self.C, self.A, len(self.C)
        out = []
        s_left = float(np.sum(times[:affected]))
        s_right = float(np.sum(times[affected + 1:]))
        direction = "left" if s_left < s_right else "right"
        lightest = _lightest_in_direction(times, C, affected, direction)
        if lightest is None:
            direction = "left" if direction == "right" else "right"
            lightest = _lightest_in_direction(times, C, affected,
                                              direction)
        if lightest is not None and C[affected] > 1:
            C2 = list(C)
            C2[affected] -= 1
            C2[lightest] += 1
            out.append((C2, list(A)))
        for donor in (affected - 1, affected + 1):
            if 0 <= donor < n and A[donor] > 1:
                A2 = list(A)
                A2[donor] -= 1
                A2[affected] += 1
                out.append((list(C), A2))
        return out

    def step(self, source: StageTimeSource) -> List[int]:
        assert not self.done
        # Live reference against the best-seen (config, assignment) —
        # same online-baseline rule as the parent.
        self.T = throughput(source.stage_times(self.C_opt, self.A_opt))
        times = source.stage_times(self.C, self.A)
        affected = _affected_index(times, self.C)

        cands = self._candidates(times, affected)
        if not cands:
            self.done = True
            return list(self.C)
        scored = [throughput(source.stage_times(c, a)) for c, a in cands]
        best = int(np.argmax(scored))   # first max wins (deterministic)
        self.C, self.A = cands[best]
        T_new = scored[best]

        if T_new > self.T:
            self.gamma = 0
            self.T = T_new
            self.C_opt, self.A_opt = list(self.C), list(self.A)
            self.trials.append(Trial(list(self.C), T_new, True,
                                     mesh=list(self.A)))
        elif T_new == self.T:
            # Plateau escape: one extra application of the same move.
            times = source.stage_times(self.C, self.A)
            affected = _affected_index(times, self.C)
            again = self._candidates(times, affected)
            if again:
                scores = [throughput(source.stage_times(c, a))
                          for c, a in again]
                j = int(np.argmax(scores))
                self.C, self.A = again[j]
                T_new = scores[j]
            improved = T_new > self.T
            self.gamma = 0 if improved else self.gamma + 1
            if improved:
                self.T = T_new
                self.C_opt, self.A_opt = list(self.C), list(self.A)
            self.trials.append(Trial(list(self.C), T_new, improved,
                                     mesh=list(self.A)))
        else:
            self.gamma += 1
            self.trials.append(Trial(list(self.C), T_new, False,
                                     mesh=list(self.A)))

        if self.gamma >= self.alpha:
            self.done = True
        return list(self.C)

    def result(self) -> RebalanceResult:
        return RebalanceResult(list(self.C_opt), float(self.T or 0.0),
                               list(self.trials), mesh=list(self.A_opt))


def odin_rebalance(config: Sequence[int], alpha: int,
                   source: StageTimeSource,
                   max_trials: int = 10_000) -> RebalanceResult:
    """Run Algorithm 1 to completion against a frozen interference state."""
    ex = OdinExplorer(config, alpha)
    for _ in range(max_trials):
        if ex.done:
            break
        ex.step(source)
    return ex.result()


# ---------------------------------------------------------------------------
# The online monitor (paper §3.1) lives in repro.schedulers: the shared
# InterferenceDetector + OdinPolicy replace the old per-algorithm
# controller.  ``OdinController`` remains importable as an alias.
# ---------------------------------------------------------------------------


def __getattr__(name: str):
    if name == "OdinController":
        from repro.schedulers.policies import OdinPolicy
        return OdinPolicy
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
