"""Paper-faithful ODIN core: pure Python/NumPy, no JAX dependency."""
from repro.core.database import (  # noqa: F401
    InterferenceScenario,
    LayerDatabase,
    paper_scenarios,
    synthetic_database,
    transformer_database,
)
from repro.core.exhaustive import (  # noqa: F401
    brute_force_partition,
    optimal_partition,
)
from repro.core.lls import LLSExplorer, lls_rebalance  # noqa: F401
from repro.core.odin import (  # noqa: F401
    OdinExplorer,
    RebalanceResult,
    Trial,
    odin_rebalance,
)
from repro.core.pipeline_state import (  # noqa: F401
    balanced_config,
    boundaries,
    pipelined_latency,
    serial_latency,
    throughput,
    utilization,
    validate_config,
    waiting_times,
)
from repro.core.events import (  # noqa: F401
    EventTimeline,
    events_for_replica,
)
from repro.core.simulator import (  # noqa: F401
    PAPER_SETTINGS,
    DatabaseQueryExecutor,
    InterferenceEvent,
    SimResult,
    SimTimeSource,
    generate_events,
    simulate,
)


def __getattr__(name):
    """Back-compat: the online controllers moved to repro.schedulers.

    ``OdinController`` / ``LLSController`` remain importable from
    ``repro.core`` as aliases of the registry policies.  Lazy so that
    ``import repro.schedulers`` (which imports repro.core submodules
    while its own policies module is still executing) cannot deadlock
    the two packages' initialisation.
    """
    aliases = {"OdinController": "OdinPolicy", "LLSController": "LLSPolicy"}
    if name in aliases:
        from repro.schedulers import policies
        return getattr(policies, aliases[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
