"""Pallas TPU Mamba2 SSD chunk scan.

The SSD dual form maps naturally onto the MXU: within a chunk the token
mixing is three dense contractions ((C·Bᵀ)∘L against x, plus the state
read/write terms); across chunks a [H, P, N] state is carried — here it
lives in VMEM scratch across the innermost (sequential) chunk grid axis,
so the recurrence never round-trips HBM.

Grid = (B, H/block_h, nc).  Head-blocking bounds the VMEM working set:
state tile is block_h × P × N fp32 (e.g. 8×64×128×4 = 256 KiB for Jamba's
d_inner = 16384 where a full-head state would be 8 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: [h, c] -> [h, c, c] lower-tri segment sums (NEG_INF above)."""
    h, c = x.shape
    cs = jnp.cumsum(x, axis=-1)
    out = cs[:, :, None] - cs[:, None, :]
    i = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    return jnp.where(i >= j, out, -jnp.inf)


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # [bh, cs, P]
    dt = dt_ref[0].astype(jnp.float32)        # [bh, cs]
    A = a_ref[...].astype(jnp.float32)        # [bh]
    Bc = b_ref[0].astype(jnp.float32)         # [cs, N]
    Cc = c_ref[0].astype(jnp.float32)         # [cs, N]

    dA = dt * A[:, None]                      # [bh, cs]
    dA_cs = jnp.cumsum(dA, axis=-1)           # [bh, cs]
    xdt = x * dt[..., None]                   # [bh, cs, P]

    # Intra-chunk (dual quadratic form): (C·Bᵀ ∘ L) @ (x·dt)
    L = jnp.exp(_segsum(dA))                  # [bh, cs, cs]
    cb = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [cs, cs]
    y_diag = jnp.einsum("ij,hij,hjp->hip", cb, L, xdt)

    # State read (inter-chunk): y += (C · h_prev) with decay
    state = state_scr[...]                    # [bh, P, N]
    decay_in = jnp.exp(dA_cs)                 # [bh, cs]
    y_off = jnp.einsum("ln,hpn,hl->hlp", Cc, state, decay_in)

    # State write: h = h * exp(sum dA) + sum decay·B⊗(x·dt)
    decay_states = jnp.exp(dA_cs[:, -1:] - dA_cs)      # [bh, cs]
    chunk_state = jnp.einsum("hl,ln,hlp->hpn", decay_states, Bc, xdt)
    state_scr[...] = (state * jnp.exp(dA_cs[:, -1])[:, None, None]
                      + chunk_state)

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *,
             chunk: int = 256, block_h: int = 8,
             interpret: bool = False) -> jnp.ndarray:
    """SSD scan (layout matches repro.models.mamba2.ssd_chunked).

    x: [b, S, H, P]; dt: [b, S, H]; A: [H]; B, C: [b, S, N].
    Returns y: [b, S, H, P].
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    block_h = min(block_h, H)
    if H % block_h:
        raise ValueError(f"H={H} not divisible by block_h={block_h}")
    nc = S // chunk
    nh = H // block_h

    # Layout: heads-major so a head-block×chunk tile is contiguous.
    xt = x.transpose(0, 2, 1, 3)              # [b, H, S, P]
    dtt = dt.transpose(0, 2, 1)               # [b, H, S]

    kernel = functools.partial(_ssd_kernel, chunk=chunk)

    yt = pl.pallas_call(
        kernel,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, block_h, chunk, P),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, block_h, chunk),
                         lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((block_h,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, chunk, P),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_h, P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, B, C)
    return yt.transpose(0, 2, 1, 3)
