"""Jit'd dispatch wrappers for the Pallas kernels.

``impl`` selection:
  * "pallas"     — compiled Pallas (TPU)
  * "interpret"  — Pallas interpret mode (CPU validation; executes the
                   kernel body in Python via the Pallas interpreter)
  * "ref"        — pure-jnp oracle (XLA; used by the dry-run path)
  * "auto"       — pallas on TPU, ref elsewhere
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref as ref_lib
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    impl = _resolve(impl)
    if impl == "ref":
        return ref_lib.flash_attention_ref(q, k, v, causal=causal,
                                           window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         block_q=block_q, block_k=block_k,
                         interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_k"))
def decode_attention(q, k, v, index, *, window: Optional[int] = None,
                     impl: str = "auto", block_k: int = 512):
    impl = _resolve(impl)
    if impl == "ref":
        return ref_lib.decode_attention_ref(q, k, v, index, window=window)
    return _decode_pallas(q, k, v, index, window=window, block_k=block_k,
                          interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "block_h", "impl"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, block_h: int = 8,
             impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return ref_lib.ssd_scan_ref(x, dt, A, B, C)
    return _ssd_pallas(x, dt, A, B, C, chunk=chunk, block_h=block_h,
                       interpret=(impl == "interpret"))
