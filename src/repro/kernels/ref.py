"""Pure-jnp oracles for every Pallas kernel (independent formulations)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """Naive full-materialization softmax attention.

    q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] -> [B, Hq, S, D].
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         index, *,
                         window: Optional[int] = None) -> jnp.ndarray:
    """q: [B, Hq, D]; k, v: [B, Hkv, S, D] -> [B, Hq, D]."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    kp = jnp.arange(S)
    mask = kp <= index
    if window is not None:
        mask &= kp > index - window
    s = jnp.where(mask[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 B: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """Token-by-token linear recurrence (independent of the chunked form).

    x: [b, S, H, P]; dt: [b, S, H]; A: [H]; B, C: [b, S, N] -> [b, S, H, P].
    h_t = h_{t-1} * exp(dt_t A) + dt_t x_t ⊗ B_t ;  y_t = h_t · C_t
    """
    b, S, H, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt * A[None, :])                       # [b, H]
        h = (h * dA[..., None, None]
             + jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt))
        y = jnp.einsum("bhpn,bn->bhp", h, Ct)
        return h, y

    h0 = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
