"""Pallas TPU flash attention (prefill/train hot spot).

Canonical online-softmax tiling: grid = (B, Hq, nq, nk) with the KV-block
dimension innermost — TPU executes the grid sequentially over the last
axis, so the (m, l, acc) running statistics live in VMEM scratch and are
carried across KV steps.  Q/K/V tiles are staged HBM→VMEM by BlockSpec;
the (block_q × block_k) score tile and the accumulator stay resident in
VMEM and feed the MXU with 128-aligned contractions when
block_q = block_k = 128 and head_dim ∈ {128, 256}.

Fully-masked KV blocks (beyond the causal frontier, or outside the
sliding window) are skipped with ``pl.when`` — for causal attention this
halves the work; for a 4k sliding window over a 32k sequence it removes
~7/8 of it.

GQA: Hkv < Hq is handled purely by the K/V index_map (kv head =
q_head // group) — KV tiles are re-read per query-head group rather than
materializing repeated heads in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int,
                  num_kv_blocks: int, causal: bool,
                  window: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # Block-level mask culling.
    live = jnp.bool_(True)
    if causal:
        # no key in this block is <= the last query position -> dead
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        # every key is older than (q - window) for all queries -> dead
        live &= k_start + block_k - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qp >= kp
        if window is not None:
            mask &= qp - kp < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"S={S} must be divisible by blocks "
                         f"({block_q}, {block_k})")
    nq, nk = S // block_q, S // block_k
    scale = D ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        num_kv_blocks=nk, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
