"""Pallas TPU decode attention (single-token GQA attention vs a long KV cache).

Decode attention is memory-bound: the whole KV cache streams HBM→VMEM
once while the query is a single token.  Tiling: grid = (B, Hkv, nk) with
the KV-block axis innermost; the query-head *group* (all Hq/Hkv query
heads sharing one KV head) rides along in a single [group, D] VMEM tile,
so each KV block is read exactly once per KV head — the GQA bandwidth
advantage is realized structurally.

Running (m, l, acc) online-softmax statistics live in VMEM scratch across
KV blocks.  The valid-length mask (cache slots beyond ``index``) and the
optional sliding window are applied per block; blocks entirely outside
the window are culled with ``pl.when``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(index_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, num_kv_blocks: int,
                   window: Optional[int]):
    ki = pl.program_id(2)
    index = index_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k
    live = k_start <= index
    if window is not None:
        live &= k_start + block_k - 1 > index - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [group, D]
        k = k_ref[0, 0].astype(jnp.float32)           # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)           # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [group, bk]
        kp = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kp <= index
        if window is not None:
            mask &= kp > index - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     index: jnp.ndarray, *,
                     window: Optional[int] = None,
                     block_k: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, D] (one token); k, v: [B, Hkv, S, D]; index: scalar int32
    position of the newest valid cache slot.  Returns [B, Hq, D]."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = Hq // Hkv
    block_k = min(block_k, S)
    if S % block_k:
        raise ValueError(f"S={S} not divisible by block_k={block_k}")
    nk = S // block_k
    scale = D ** -0.5

    qg = q.reshape(B, Hkv, group, D)
    index = jnp.asarray(index, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, num_kv_blocks=nk,
        window=window)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
    )(index, qg, k, v)
    return out.reshape(B, Hq, D)
