"""Online quantile sketches: constant-memory, mergeable, deterministic.

:class:`QuantileSketch` is a merging t-digest (Dunning & Ertl) with the
``k1`` (arcsine) scale function: incoming values accumulate in a small
buffer; when the buffer fills, buffer + existing centroids are sorted
and re-clustered in one vectorized pass, so the structure holds at most
``~compression / 2`` weighted centroids no matter how many values it
has seen.  The arcsine scale concentrates centroid resolution at the
distribution's tails — tail centroids are near-singletons — which is
what makes p99 reads accurate at a few kilobytes of state.

Guarantees (see docs/TELEMETRY.md "Sketch guarantees"):

* **Deterministic.** No randomization anywhere: the same values in the
  same order produce bit-identical centroids, and merging the same
  sketches produces bit-identical results.  Runs stay reproducible
  from ``(workload, seed, scheduler)`` alone.
* **Exact below the buffer size.** Until the first compression
  (``n <= buffer_size`` values, no merges of compressed sketches)
  quantile reads fall back to the exact sorted-buffer computation and
  match ``np.percentile(values, pct)`` to the ulp.
* **Bounded tail error.** After compression, a quantile read at ``q``
  interpolates between centroids whose width in quantile space is at
  most ``2π · sqrt(q(1-q)) / compression``; at the default
  ``compression=512`` the p99 read sits within ±0.12 percentile-points
  of the exact order statistic, which lands well inside the documented
  ≤1% relative error on p99 for the serving-latency distributions this
  repo produces (property-tested in tests/test_telemetry.py).
* **Mergeable.** ``merge`` folds another sketch's centroids into this
  one with the same re-clustering pass, so per-replica sketches fold
  into fleet percentiles (:class:`repro.cluster.ClusterTrace` streaming
  mode) with the same error bound as a single fleet-wide sketch.

Memory: two float64 arrays of ``<= compression / 2 + 2`` centroids plus
a buffer of ``<= buffer_size`` pending values — a few KB, flat in the
number of observations.
"""

from __future__ import annotations

import math
from typing import Iterable, List, Optional

import numpy as np

#: Default t-digest compression (δ).  ~δ/2 centroids; tail clusters are
#: near-singletons, so p99 error is far below the documented 1% bound.
DEFAULT_COMPRESSION = 512

#: Default pending-value buffer.  Reads on sketches that never exceeded
#: this many values are exact.
DEFAULT_BUFFER = 4096


class QuantileSketch:
    """Mergeable streaming quantile estimator (merging t-digest, k1).

    >>> s = QuantileSketch()
    >>> s.add(np.random.default_rng(0).exponential(size=100_000))
    >>> abs(s.quantile(0.99) - 4.6) < 0.1
    True
    """

    __slots__ = ("compression", "buffer_size", "_means", "_weights", "_buf",
                 "_buffered", "_n", "_min", "_max", "_sum")

    def __init__(self, compression: int = DEFAULT_COMPRESSION,
                 buffer_size: int = DEFAULT_BUFFER):
        if compression < 16:
            raise ValueError(f"compression must be >= 16, got {compression}")
        if buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {buffer_size}")
        self.compression = int(compression)
        self.buffer_size = int(buffer_size)
        self._means: Optional[np.ndarray] = None    # sorted centroid means
        self._weights: Optional[np.ndarray] = None  # matching weights
        self._buf: List[np.ndarray] = []            # pending value arrays
        self._buffered = 0
        self._n = 0
        self._min = math.inf
        self._max = -math.inf
        self._sum = 0.0

    # -- ingest --------------------------------------------------------------
    def add(self, values) -> None:
        """Fold an array (or scalar) of observations into the sketch."""
        arr = np.atleast_1d(np.asarray(values, dtype=np.float64))
        if arr.size == 0:
            return
        if not np.all(np.isfinite(arr)):
            raise ValueError("sketch values must be finite")
        # Copy: callers (the streaming run loop) reuse their arrays as
        # ring scratch, so the buffer must not hold views into them.
        self._buf.append(arr.copy())
        self._buffered += arr.size
        self._n += arr.size
        self._sum += float(arr.sum())
        lo, hi = float(arr.min()), float(arr.max())
        if lo < self._min:
            self._min = lo
        if hi > self._max:
            self._max = hi
        if self._buffered >= self.buffer_size:
            self._compress()

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other``'s state into this sketch (``other`` is not
        modified).  Returns ``self`` for chaining."""
        if other._n == 0:
            return self
        for arr in other._buf:
            self._buf.append(arr.copy())
        self._buffered += other._buffered
        if other._means is not None:
            # Centroids carry weight > 1: enter the merge through the
            # weighted compression path, not the value buffer.
            self._compress(extra=(other._means, other._weights))
        elif self._buffered >= self.buffer_size:
            self._compress()
        self._n += other._n
        self._sum += other._sum
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        return self

    def copy(self) -> "QuantileSketch":
        out = QuantileSketch(self.compression, self.buffer_size)
        out._means = None if self._means is None else self._means.copy()
        out._weights = None if self._weights is None else self._weights.copy()
        out._buf = [a.copy() for a in self._buf]
        out._buffered = self._buffered
        out._n = self._n
        out._min = self._min
        out._max = self._max
        out._sum = self._sum
        return out

    @classmethod
    def merged(cls, sketches: Iterable["QuantileSketch"]) -> "QuantileSketch":
        """New sketch equivalent to having seen every input's values."""
        sketches = list(sketches)
        if not sketches:
            return cls()
        out = sketches[0].copy()
        for s in sketches[1:]:
            out.merge(s)
        return out

    # -- compression ---------------------------------------------------------
    def _k_index(self, q_mid: np.ndarray) -> np.ndarray:
        """k1 scale cluster index for centroid midpoint quantiles."""
        k = (self.compression / (2.0 * math.pi)) * np.arcsin(
            np.clip(2.0 * q_mid - 1.0, -1.0, 1.0))
        return np.floor(k).astype(np.int64)

    def _compress(self, extra=None) -> None:
        """Re-cluster centroids + buffered values in one vectorized pass."""
        parts_m, parts_w = [], []
        if self._means is not None:
            parts_m.append(self._means)
            parts_w.append(self._weights)
        if self._buf:
            buffered = np.concatenate(self._buf)
            parts_m.append(buffered)
            parts_w.append(np.ones(len(buffered)))
        if extra is not None:
            parts_m.append(extra[0])
            parts_w.append(extra[1])
        if not parts_m:
            return
        means = np.concatenate(parts_m)
        weights = np.concatenate(parts_w)
        order = np.argsort(means, kind="stable")
        means = means[order]
        weights = weights[order]
        total = weights.sum()
        q_mid = (np.cumsum(weights) - 0.5 * weights) / total
        idx = self._k_index(q_mid)
        idx -= idx[0]                     # contiguous non-negative bins
        w_sum = np.bincount(idx, weights=weights)
        m_sum = np.bincount(idx, weights=weights * means)
        occupied = w_sum > 0
        self._weights = w_sum[occupied]
        self._means = m_sum[occupied] / self._weights
        self._buf = []
        self._buffered = 0

    # -- reads ---------------------------------------------------------------
    @property
    def n(self) -> int:
        """Number of observations folded in."""
        return self._n

    @property
    def min(self) -> float:
        return self._min if self._n else math.nan

    @property
    def max(self) -> float:
        return self._max if self._n else math.nan

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._n if self._n else math.nan

    def __len__(self) -> int:
        return self._n

    def quantile(self, q: float) -> float:
        """Estimated value at quantile ``q`` in ``[0, 1]``."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self._n == 0:
            return math.nan
        if self._means is None:
            # Never compressed: the buffer holds every value — exact.
            values = np.sort(np.concatenate(self._buf))
            self._buf = [values]          # keep the sort for reuse
            return _percentile_sorted(values, 100.0 * q)
        self._compress()
        m, w = self._means, self._weights
        c = np.cumsum(w)
        total = c[-1]
        mids = c - 0.5 * w
        xs = np.concatenate(([0.0], mids, [total]))
        ys = np.concatenate(([self._min], m, [self._max]))
        return float(np.interp(q * total, xs, ys))

    def percentile(self, pct: float) -> float:
        """Estimated value at percentile ``pct`` in ``[0, 100]``."""
        return self.quantile(pct / 100.0)

    def cdf(self, x: float) -> float:
        """Estimated fraction of observations strictly below ``x``."""
        if self._n == 0:
            return math.nan
        if self._means is None:
            values = np.concatenate(self._buf)
            return float(np.count_nonzero(values < x)) / self._n
        self._compress()
        if x <= self._min:
            return 0.0
        if x > self._max:
            return 1.0
        m, w = self._means, self._weights
        c = np.cumsum(w)
        total = c[-1]
        mids = c - 0.5 * w
        xs = np.concatenate(([self._min], m, [self._max]))
        ys = np.concatenate(([0.0], mids, [total]))
        return float(np.interp(x, xs, ys) / total)

    def __repr__(self) -> str:
        cent = 0 if self._means is None else len(self._means)
        return (f"QuantileSketch(n={self._n}, centroids={cent}, "
                f"compression={self.compression})")


def _percentile_sorted(sorted_values: np.ndarray, pct: float) -> float:
    """``np.percentile(values, pct)`` (linear method) on an
    already-sorted array, without re-sorting.

    Replicates numpy's lerp — including the ``t >= 0.5`` reversal that
    keeps the interpolation exact at the endpoints — so reads off a
    cached sort are bit-identical to a fresh ``np.percentile`` call.
    NaN-safe: an empty array reads as NaN instead of raising.
    """
    n = len(sorted_values)
    if n == 0:
        return math.nan
    if n == 1:
        return float(sorted_values[0])
    virtual = (pct / 100.0) * (n - 1)
    lo = int(math.floor(virtual))
    lo = min(max(lo, 0), n - 2)
    t = virtual - lo
    a = float(sorted_values[lo])
    b = float(sorted_values[lo + 1])
    if t >= 0.5:
        return b - (b - a) * (1.0 - t)
    return a + (b - a) * t
