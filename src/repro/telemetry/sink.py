"""MetricsSink: the periodic snapshot hook for live observability.

A sink receives metric snapshots *during* a run — the operator-facing
signal dense traces cannot provide.  ``PipelineRunner``,
``ServingEngine.serve``, and the cluster backends accept
``metrics_sink=`` and call :meth:`MetricsSink.emit` roughly every
``sink_interval`` served queries (plus once at run end), passing the
current :meth:`MetricsRegistry.snapshot` dict.

Emission cadence is measured in *queries*, not wall time, so runs stay
deterministic: the same workload and seed produce the same sequence of
snapshots.

Built-ins cover the common cases; anything with an
``emit(snapshot: dict) -> None`` method satisfies the protocol
(structural typing — no subclassing required).
"""

from __future__ import annotations

import json
import sys
from typing import Callable, Dict, List, Optional, Protocol, runtime_checkable


@runtime_checkable
class MetricsSink(Protocol):
    """Anything that can receive periodic metric snapshots."""

    def emit(self, snapshot: Dict[str, object]) -> None:
        """Receive one snapshot.  Must not mutate it."""
        ...


class MemorySink:
    """Collects snapshots in a list — tests and notebook plotting."""

    def __init__(self):
        self.snapshots: List[Dict[str, object]] = []

    def emit(self, snapshot: Dict[str, object]) -> None:
        self.snapshots.append(snapshot)

    def __len__(self) -> int:
        return len(self.snapshots)

    @property
    def last(self) -> Optional[Dict[str, object]]:
        return self.snapshots[-1] if self.snapshots else None


class CallbackSink:
    """Adapts a plain callable into a sink."""

    def __init__(self, fn: Callable[[Dict[str, object]], None]):
        self._fn = fn

    def emit(self, snapshot: Dict[str, object]) -> None:
        self._fn(snapshot)


class ThresholdRule:
    """One alerting rule evaluated against every snapshot.

    ``metric`` names a snapshot entry (full registry name); for Summary
    metrics, ``quantile`` selects a quantile subkey (e.g. ``"0.99"``).
    ``above=True`` fires when the value exceeds ``threshold``; with
    ``above=False`` the comparison flips.  ``clear`` is the hysteresis
    bound the value must re-cross before the rule can fire again —
    defaulting to ``threshold`` itself (no hysteresis band).  A rule
    with ``clear`` strictly inside the firing region raises: it could
    never reset.
    """

    def __init__(self, metric: str, threshold: float,
                 quantile: Optional[str] = None, above: bool = True,
                 clear: Optional[float] = None):
        self.metric = metric
        self.quantile = quantile
        self.threshold = float(threshold)
        self.above = bool(above)
        self.clear = self.threshold if clear is None else float(clear)
        if (self.clear > self.threshold) == self.above and \
                self.clear != self.threshold:
            side = "above" if self.above else "below"
            raise ValueError(
                f"rule on {metric!r}: clear={self.clear:g} is {side} "
                f"threshold={self.threshold:g} — the rule would fire "
                "and never reset")
        self.firing = False

    @property
    def key(self) -> str:
        return (self.metric if self.quantile is None
                else f"{self.metric}{{q={self.quantile}}}")

    def extract(self, snapshot: Dict[str, object]):
        val = snapshot.get(self.metric)
        if isinstance(val, dict):
            if self.quantile is None:
                return None
            val = val.get("quantiles", {}).get(self.quantile)
        elif self.quantile is not None:
            return None
        if val is None:
            return None
        val = float(val)
        return None if val != val else val      # NaN -> no signal

    def breached(self, value: float) -> bool:
        return value > self.threshold if self.above \
            else value < self.threshold

    def cleared(self, value: float) -> bool:
        return value <= self.clear if self.above else value >= self.clear


class ThresholdSink:
    """Fires callbacks when metrics cross thresholds — with hysteresis.

    Wraps the snapshot stream in edge-triggered alerting: each
    :class:`ThresholdRule` fires its callback once when the watched
    value enters the breach region, then stays silent until the value
    re-crosses the rule's ``clear`` bound (hysteresis — a value
    oscillating around the threshold produces one incident, not one
    per snapshot).  Every firing is appended to :attr:`incidents` as
    ``{"rule", "metric", "value", "threshold", "snapshot_index"}``, so
    headless runs (benchmarks, soak tests) can assert on alert history
    without a callback at all.

    >>> sink = ThresholdSink()
    >>> sink.add_rule("repro_latency_seconds", 0.5, quantile="0.99",
    ...               clear=0.4, callback=page_operator)
    >>> # ... run with metrics_sink=sink ...
    >>> len(sink.incidents)
    """

    def __init__(self, on_incident: Optional[Callable] = None):
        self.rules: List[ThresholdRule] = []
        self._callbacks: List[Optional[Callable]] = []
        self._on_incident = on_incident
        self.incidents: List[Dict[str, object]] = []
        self._seen = 0

    def add_rule(self, metric: str, threshold: float,
                 quantile: Optional[str] = None, above: bool = True,
                 clear: Optional[float] = None,
                 callback: Optional[Callable] = None) -> ThresholdRule:
        rule = ThresholdRule(metric, threshold, quantile=quantile,
                             above=above, clear=clear)
        self.rules.append(rule)
        self._callbacks.append(callback)
        return rule

    def emit(self, snapshot: Dict[str, object]) -> None:
        idx = self._seen
        self._seen += 1
        for rule, cb in zip(self.rules, self._callbacks):
            value = rule.extract(snapshot)
            if value is None:
                continue
            if rule.firing:
                if rule.cleared(value):
                    rule.firing = False
                continue
            if rule.breached(value):
                rule.firing = True
                incident = {"rule": rule.key, "metric": rule.metric,
                            "value": value,
                            "threshold": rule.threshold,
                            "snapshot_index": idx}
                self.incidents.append(incident)
                if cb is not None:
                    cb(incident)
                if self._on_incident is not None:
                    self._on_incident(incident)


class JsonLinesSink:
    """Appends one JSON object per snapshot to a stream or file.

    >>> sink = JsonLinesSink("metrics.jsonl")   # or JsonLinesSink()
    >>> # ... run with metrics_sink=sink ...
    >>> sink.close()
    """

    def __init__(self, path_or_stream=None):
        if path_or_stream is None:
            self._stream = sys.stdout
            self._owns = False
        elif hasattr(path_or_stream, "write"):
            self._stream = path_or_stream
            self._owns = False
        else:
            self._stream = open(path_or_stream, "a")
            self._owns = True

    def emit(self, snapshot: Dict[str, object]) -> None:
        self._stream.write(json.dumps(snapshot) + "\n")
        self._stream.flush()

    def close(self) -> None:
        if self._owns:
            self._stream.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
