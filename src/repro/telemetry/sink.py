"""MetricsSink: the periodic snapshot hook for live observability.

A sink receives metric snapshots *during* a run — the operator-facing
signal dense traces cannot provide.  ``PipelineRunner``,
``ServingEngine.serve``, and the cluster backends accept
``metrics_sink=`` and call :meth:`MetricsSink.emit` roughly every
``sink_interval`` served queries (plus once at run end), passing the
current :meth:`MetricsRegistry.snapshot` dict.

Emission cadence is measured in *queries*, not wall time, so runs stay
deterministic: the same workload and seed produce the same sequence of
snapshots.

Built-ins cover the common cases; anything with an
``emit(snapshot: dict) -> None`` method satisfies the protocol
(structural typing — no subclassing required).
"""

from __future__ import annotations

import json
import sys
from typing import Callable, Dict, List, Optional, Protocol, runtime_checkable


@runtime_checkable
class MetricsSink(Protocol):
    """Anything that can receive periodic metric snapshots."""

    def emit(self, snapshot: Dict[str, object]) -> None:
        """Receive one snapshot.  Must not mutate it."""
        ...


class MemorySink:
    """Collects snapshots in a list — tests and notebook plotting."""

    def __init__(self):
        self.snapshots: List[Dict[str, object]] = []

    def emit(self, snapshot: Dict[str, object]) -> None:
        self.snapshots.append(snapshot)

    def __len__(self) -> int:
        return len(self.snapshots)

    @property
    def last(self) -> Optional[Dict[str, object]]:
        return self.snapshots[-1] if self.snapshots else None


class CallbackSink:
    """Adapts a plain callable into a sink."""

    def __init__(self, fn: Callable[[Dict[str, object]], None]):
        self._fn = fn

    def emit(self, snapshot: Dict[str, object]) -> None:
        self._fn(snapshot)


class JsonLinesSink:
    """Appends one JSON object per snapshot to a stream or file.

    >>> sink = JsonLinesSink("metrics.jsonl")   # or JsonLinesSink()
    >>> # ... run with metrics_sink=sink ...
    >>> sink.close()
    """

    def __init__(self, path_or_stream=None):
        if path_or_stream is None:
            self._stream = sys.stdout
            self._owns = False
        elif hasattr(path_or_stream, "write"):
            self._stream = path_or_stream
            self._owns = False
        else:
            self._stream = open(path_or_stream, "a")
            self._owns = True

    def emit(self, snapshot: Dict[str, object]) -> None:
        self._stream.write(json.dumps(snapshot) + "\n")
        self._stream.flush()

    def close(self) -> None:
        if self._owns:
            self._stream.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
