"""Constant-memory metric primitives and the registry/exporter layer.

Four metric kinds, mirroring the Prometheus data model:

* :class:`Counter` — monotone float count (queries served, shed, ...).
* :class:`Gauge` — last-written value (queue depth, active replicas).
* :class:`Summary` — a :class:`~repro.telemetry.sketch.QuantileSketch`
  exposed with Prometheus summary semantics (quantile series plus
  ``_sum`` / ``_count``).
* :class:`Histogram` — fixed-bucket counts with cumulative
  ``_bucket{le=...}`` exposition; aggregates across hosts by plain
  addition, no sketch merge required.

:class:`MetricsRegistry` is the get-or-create namespace for them, with
two exposition formats:

* :meth:`MetricsRegistry.prometheus` — the Prometheus text exposition
  format (``# HELP`` / ``# TYPE`` headers, ``quantile=`` labels), ready
  to serve from a ``/metrics`` endpoint or write to a ``.prom`` file.
* :meth:`MetricsRegistry.snapshot` / :meth:`MetricsRegistry.to_json` —
  a plain-dict / JSON form for programmatic consumers and the
  :class:`~repro.telemetry.sink.MetricsSink` hook.

Registries merge (:meth:`MetricsRegistry.merge`) by summing counters,
taking the last gauge write, and folding summary sketches — so
per-replica registries roll up into fleet registries losslessly for
counters and within sketch tolerance for quantiles.
"""

from __future__ import annotations

import json
import math
import re
from typing import Dict, Optional, Tuple

import numpy as np

from .sketch import QuantileSketch

_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")

#: Quantiles a Summary exposes in snapshots and Prometheus text.
SUMMARY_QUANTILES = (0.5, 0.9, 0.99)


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "help", "_value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = _check_name(name)
        self.help = help
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-written instantaneous value."""

    __slots__ = ("name", "help", "_value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = _check_name(name)
        self.help = help
        self._value = math.nan

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value = (0.0 if math.isnan(self._value)
                       else self._value) + amount

    @property
    def value(self) -> float:
        return self._value


class Summary:
    """Quantile sketch with Prometheus summary exposition."""

    __slots__ = ("name", "help", "sketch")
    kind = "summary"

    def __init__(self, name: str, help: str = "",
                 sketch: Optional[QuantileSketch] = None):
        self.name = _check_name(name)
        self.help = help
        self.sketch = sketch if sketch is not None else QuantileSketch()

    def observe(self, values) -> None:
        self.sketch.add(values)

    def quantile(self, q: float) -> float:
        return self.sketch.quantile(q)

    @property
    def count(self) -> int:
        return self.sketch.n

    @property
    def sum(self) -> float:
        return self.sketch.sum


class Histogram:
    """Fixed-bucket counts with Prometheus histogram exposition.

    Unlike a :class:`Summary` (whose t-digest sketch needs the custom
    merge in this package), fixed buckets aggregate across hosts with
    plain addition — any Prometheus-compatible backend can sum the
    ``_bucket`` series.  ``buckets`` are the finite upper bounds; the
    implicit ``+Inf`` bucket is always present.  Exposition is
    cumulative (``{le="x"}``), per the Prometheus data model.
    """

    __slots__ = ("name", "help", "buckets", "_counts", "_sum")
    kind = "histogram"

    #: Default latency-style buckets (seconds), roughly log-spaced.
    DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                       1.0, 2.5, 5.0, 10.0)

    def __init__(self, name: str, help: str = "", buckets=None):
        self.name = _check_name(name)
        self.help = help
        b = tuple(float(x) for x in
                  (buckets if buckets is not None else self.DEFAULT_BUCKETS))
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError("histogram buckets must be strictly "
                             "increasing and non-empty")
        if any(math.isinf(x) for x in b):
            raise ValueError("the +Inf bucket is implicit; pass finite "
                             "upper bounds only")
        self.buckets = b
        self._counts = [0] * (len(b) + 1)   # last = +Inf overflow
        self._sum = 0.0

    def observe(self, values) -> None:
        """Fold one value or an array of values into the buckets."""
        arr = np.atleast_1d(np.asarray(values, dtype=float))
        if arr.size == 0:
            return
        idx = np.searchsorted(np.asarray(self.buckets), arr, side="left")
        for i, c in zip(*np.unique(idx, return_counts=True)):
            self._counts[int(i)] += int(c)
        self._sum += float(arr.sum())

    @property
    def count(self) -> int:
        return sum(self._counts)

    @property
    def sum(self) -> float:
        return self._sum

    def cumulative(self) -> Dict[str, int]:
        """``{le: cumulative count}`` including the ``+Inf`` bucket."""
        out: Dict[str, int] = {}
        running = 0
        for le, c in zip(self.buckets, self._counts):
            running += c
            out[f"{le:g}"] = running
        out["+Inf"] = running + self._counts[-1]
        return out

    def merge_from(self, other: "Histogram") -> None:
        if other.buckets != self.buckets:
            raise ValueError(
                f"cannot merge histogram {self.name!r}: bucket bounds "
                f"differ ({self.buckets} vs {other.buckets})")
        for i, c in enumerate(other._counts):
            self._counts[i] += c
        self._sum += other._sum


class MetricsRegistry:
    """Namespace of metrics with get-or-create accessors and export."""

    def __init__(self, namespace: str = ""):
        if namespace:
            _check_name(namespace)
        self.namespace = namespace
        self._metrics: Dict[str, object] = {}

    # -- get-or-create -------------------------------------------------------
    def _get(self, cls, name: str, help: str):
        metric = self._metrics.get(name)
        if metric is None:
            metric = cls(name, help)
            self._metrics[name] = metric
        elif not isinstance(metric, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(metric).__name__}, not {cls.__name__}")
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def summary(self, name: str, help: str = "") -> Summary:
        return self._get(Summary, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> Histogram:
        metric = self._metrics.get(name)
        if metric is None:
            metric = Histogram(name, help, buckets=buckets)
            self._metrics[name] = metric
        elif not isinstance(metric, Histogram):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(metric).__name__}, not Histogram")
        return metric

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    # -- merge ---------------------------------------------------------------
    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` into this registry: counters add, gauges take
        ``other``'s value when set, summaries merge sketches."""
        for metric in other:
            if isinstance(metric, Counter):
                self.counter(metric.name, metric.help).inc(metric.value)
            elif isinstance(metric, Gauge):
                if not math.isnan(metric.value):
                    self.gauge(metric.name, metric.help).set(metric.value)
            elif isinstance(metric, Summary):
                mine = self.summary(metric.name, metric.help)
                mine.sketch.merge(metric.sketch)
            elif isinstance(metric, Histogram):
                self.histogram(metric.name, metric.help,
                               buckets=metric.buckets).merge_from(metric)
        return self

    # -- export --------------------------------------------------------------
    def _full_name(self, name: str) -> str:
        return f"{self.namespace}_{name}" if self.namespace else name

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict form of every metric — the sink payload."""
        out: Dict[str, object] = {}
        for metric in self:
            name = self._full_name(metric.name)
            if isinstance(metric, Summary):
                out[name] = {
                    "count": metric.count,
                    "sum": metric.sum,
                    "quantiles": {f"{q:g}": metric.quantile(q)
                                  for q in SUMMARY_QUANTILES},
                }
            elif isinstance(metric, Histogram):
                out[name] = {
                    "count": metric.count,
                    "sum": metric.sum,
                    "buckets": metric.cumulative(),
                }
            else:
                out[name] = metric.value
        return out

    def to_json(self, **dumps_kwargs) -> str:
        return json.dumps(self.snapshot(), **dumps_kwargs)

    def prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        for metric in self:
            name = self._full_name(metric.name)
            if metric.help:
                lines.append(f"# HELP {name} {metric.help}")
            lines.append(f"# TYPE {name} {metric.kind}")
            if isinstance(metric, Summary):
                for q in SUMMARY_QUANTILES:
                    lines.append(f'{name}{{quantile="{q:g}"}} '
                                 f"{_fmt(metric.quantile(q))}")
                lines.append(f"{name}_sum {_fmt(metric.sum)}")
                lines.append(f"{name}_count {metric.count}")
            elif isinstance(metric, Histogram):
                for le, c in metric.cumulative().items():
                    lines.append(f'{name}_bucket{{le="{le}"}} {c}')
                lines.append(f"{name}_sum {_fmt(metric.sum)}")
                lines.append(f"{name}_count {metric.count}")
            else:
                lines.append(f"{name} {_fmt(metric.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt(value: float) -> str:
    if math.isnan(value):
        return "NaN"
    if math.isinf(value):
        return "+Inf" if value > 0 else "-Inf"
    return repr(float(value))


def render_export(registry: MetricsRegistry, fmt: str) -> str:
    """Render a registry in ``fmt`` ∈ {"prometheus", "json"}."""
    if fmt == "prometheus":
        return registry.prometheus()
    if fmt == "json":
        return registry.to_json(indent=2, sort_keys=True) + "\n"
    raise ValueError(f"unknown export format {fmt!r}")


def export_path_format(path: str) -> Tuple[str, str]:
    """Infer export format from a file extension: ``.prom``/``.txt`` →
    prometheus, anything else → json.  Returns ``(path, fmt)``."""
    lower = path.lower()
    if lower.endswith((".prom", ".txt")):
        return path, "prometheus"
    return path, "json"
