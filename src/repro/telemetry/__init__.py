"""repro.telemetry: streaming observability (docs/TELEMETRY.md).

Constant-memory online metrics for 10M+-query runs: mergeable quantile
sketches (:class:`QuantileSketch`), windowed rollups
(:class:`WindowedRollup`), a Prometheus/JSON metrics registry
(:class:`MetricsRegistry`), periodic snapshot sinks
(:class:`MetricsSink` and friends), and the ``trace_mode="streaming"``
result types (:class:`StreamingTrace`, :class:`StreamingClusterTrace`)
that expose the dense ``summary()`` surface at flat memory.

This package imports nothing from the rest of ``repro``: the run loops
depend on telemetry, never the reverse.
"""

from repro.telemetry.metrics import (
    SUMMARY_QUANTILES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Summary,
    export_path_format,
    render_export,
)
from repro.telemetry.rollup import DEFAULT_MAX_WINDOWS, WindowedRollup
from repro.telemetry.sink import (
    CallbackSink,
    JsonLinesSink,
    MemorySink,
    MetricsSink,
    ThresholdRule,
    ThresholdSink,
)
from repro.telemetry.sketch import (
    DEFAULT_BUFFER,
    DEFAULT_COMPRESSION,
    QuantileSketch,
)
from repro.telemetry.streaming import (
    DEFAULT_SINK_INTERVAL,
    StreamingClusterTrace,
    StreamingCollector,
    StreamingTrace,
)

__all__ = [
    "QuantileSketch",
    "DEFAULT_COMPRESSION",
    "DEFAULT_BUFFER",
    "WindowedRollup",
    "DEFAULT_MAX_WINDOWS",
    "Counter",
    "Gauge",
    "Histogram",
    "Summary",
    "MetricsRegistry",
    "SUMMARY_QUANTILES",
    "render_export",
    "export_path_format",
    "MetricsSink",
    "MemorySink",
    "CallbackSink",
    "JsonLinesSink",
    "ThresholdRule",
    "ThresholdSink",
    "StreamingCollector",
    "StreamingTrace",
    "StreamingClusterTrace",
    "DEFAULT_SINK_INTERVAL",
]
