"""Windowed rollups: dense timelines at constant memory.

Dense traces answer "what did load look like over time" by histogramming
the full per-query arrival/completion arrays after the run
(:meth:`repro.workloads.PipelineTrace.load_profile`).  Streaming mode
has no such arrays, so :class:`WindowedRollup` maintains the same
profile online: fixed-width time buckets holding arrival / completion /
shed counts and latency aggregates, with bounded retention.

Retention policies once the run outgrows ``max_windows`` buckets:

* ``"collapse"`` (default) — double the bucket width and pairwise-merge,
  so the rollup always covers the *whole* run in at most ``max_windows``
  buckets at progressively coarser resolution.  This is what
  :meth:`StreamingTrace.load_profile` needs: a full-run profile.
* ``"ring"`` — keep the most recent ``max_windows`` buckets and drop the
  oldest, for live dashboards that only care about the recent past.

All counters are plain float64 arrays of length ``max_windows`` — flat
memory regardless of run length.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

DEFAULT_MAX_WINDOWS = 256


class WindowedRollup:
    """Time-bucketed arrival/completion/latency aggregates.

    Parameters
    ----------
    width:
        Bucket width in driver time units.  ``None`` (default) defers
        the choice to the first observation batch: the width is picked
        so the batch's span fills ~1/8 of the window budget, which lets
        short runs keep fine resolution while long runs start coarse.
    max_windows:
        Retention budget (number of buckets).
    retention:
        ``"collapse"`` or ``"ring"`` (see module docstring).
    """

    __slots__ = ("width", "max_windows", "retention", "start",
                 "arrivals", "completions", "shed",
                 "latency_sum", "latency_max", "_num")

    def __init__(self, width: float = None,
                 max_windows: int = DEFAULT_MAX_WINDOWS,
                 retention: str = "collapse"):
        if max_windows < 2:
            raise ValueError(f"max_windows must be >= 2, got {max_windows}")
        if retention not in ("collapse", "ring"):
            raise ValueError(f"unknown retention {retention!r}")
        if width is not None and width <= 0:
            raise ValueError(f"width must be positive, got {width}")
        self.width = None if width is None else float(width)
        self.max_windows = int(max_windows)
        self.retention = retention
        self.start = 0.0                  # time of bucket 0's left edge
        self.arrivals = np.zeros(max_windows)
        self.completions = np.zeros(max_windows)
        self.shed = np.zeros(max_windows)
        self.latency_sum = np.zeros(max_windows)
        self.latency_max = np.zeros(max_windows)
        self._num = 0                     # occupied buckets

    # -- ingest --------------------------------------------------------------
    def observe_arrivals(self, times: np.ndarray) -> None:
        self._scatter(times, self.arrivals)

    def observe_completions(self, times: np.ndarray,
                            latencies: np.ndarray = None) -> None:
        idx = self._scatter(times, self.completions)
        if latencies is not None and idx is not None:
            lat = np.asarray(latencies, dtype=np.float64)
            np.add.at(self.latency_sum, idx, lat)
            np.maximum.at(self.latency_max, idx, lat)

    def observe_shed(self, times: np.ndarray) -> None:
        self._scatter(times, self.shed)

    def _scatter(self, times, target: np.ndarray):
        times = np.atleast_1d(np.asarray(times, dtype=np.float64))
        if times.size == 0:
            return None
        hi = float(times.max())
        if self.width is None:
            span = max(hi - self.start, 1e-12)
            self.width = span / max(self.max_windows // 8, 1)
        self._cover(hi)
        idx = self._index(times)
        np.add.at(target, idx, 1.0)
        return idx

    def _index(self, times: np.ndarray) -> np.ndarray:
        idx = np.floor((times - self.start) / self.width).astype(np.int64)
        # Ring mode can be asked about times older than its horizon;
        # clamp them into the oldest retained bucket rather than raise.
        return np.clip(idx, 0, self.max_windows - 1)

    def _cover(self, t: float) -> None:
        """Grow retention until time ``t`` lands inside the window set."""
        needed = int(np.floor((t - self.start) / self.width)) + 1
        while needed > self.max_windows:
            if self.retention == "collapse":
                self._collapse()
            else:
                self._shift(needed - self.max_windows)
            needed = int(np.floor((t - self.start) / self.width)) + 1
        self._num = max(self._num, needed)

    def _collapse(self) -> None:
        """Double bucket width; pairwise-merge so coverage doubles."""
        half = self.max_windows // 2
        for arr in (self.arrivals, self.completions, self.shed,
                    self.latency_sum):
            arr[:half] = arr[0::2] + arr[1::2]
            arr[half:] = 0.0
        lm = self.latency_max
        lm[:half] = np.maximum(lm[0::2], lm[1::2])
        lm[half:] = 0.0
        self.width *= 2.0
        self._num = (self._num + 1) // 2

    def _shift(self, k: int) -> None:
        """Ring retention: drop the ``k`` oldest buckets."""
        k = min(k, self.max_windows)
        for arr in (self.arrivals, self.completions, self.shed,
                    self.latency_sum, self.latency_max):
            arr[:-k] = arr[k:]
            arr[-k:] = 0.0
        self.start += k * self.width
        self._num = max(self._num - k, 0)

    # -- merge ---------------------------------------------------------------
    def merge(self, other: "WindowedRollup") -> "WindowedRollup":
        """Fold ``other``'s buckets into this rollup.

        Buckets are rebinned by midpoint when widths differ — an
        approximation consistent with the rollup's own resolution
        (counts are conserved exactly; placement error is bounded by
        one bucket width).
        """
        if other.width is None or other._num == 0:
            return self
        if self.width is None:
            self.width = other.width
            self.start = other.start
        mids = (other.start
                + (np.arange(other.max_windows) + 0.5) * other.width)
        occupied = (other.arrivals + other.completions + other.shed) > 0
        mids = mids[occupied]
        if mids.size == 0:
            return self
        self._cover(float(mids.max()))
        idx = self._index(mids)
        np.add.at(self.arrivals, idx, other.arrivals[occupied])
        np.add.at(self.completions, idx, other.completions[occupied])
        np.add.at(self.shed, idx, other.shed[occupied])
        np.add.at(self.latency_sum, idx, other.latency_sum[occupied])
        np.maximum.at(self.latency_max, idx, other.latency_max[occupied])
        return self

    # -- reads ---------------------------------------------------------------
    @property
    def num_windows(self) -> int:
        """Occupied bucket count."""
        return self._num

    def edges(self) -> np.ndarray:
        """Left edges of the occupied buckets."""
        w = self.width if self.width is not None else 1.0
        return self.start + np.arange(self._num) * w

    def rates(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(window_starts, offered_qps, achieved_qps)`` over occupied
        buckets — the streaming analogue of
        :meth:`PipelineTrace.load_profile` (offered counts shed
        arrivals, matching the dense definition)."""
        n = self._num
        if n == 0 or self.width is None:
            z = np.empty(0)
            return z, z.copy(), z.copy()
        offered = (self.arrivals[:n] + self.shed[:n]) / self.width
        achieved = self.completions[:n] / self.width
        return self.edges(), offered, achieved

    def __repr__(self) -> str:
        return (f"WindowedRollup(windows={self._num}/{self.max_windows}, "
                f"width={self.width}, retention={self.retention!r})")
