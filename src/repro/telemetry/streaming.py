"""Streaming trace types: the dense metric surface at flat memory.

:class:`StreamingCollector` is the online accumulator the run loops
feed instead of dense per-query arrays when ``trace_mode="streaming"``:
quantile sketches for latency / queue delay / throughput, exact
counters for everything countable (admitted, shed, serial, SLO-met,
sums for means), a :class:`~repro.telemetry.rollup.WindowedRollup` for
the load profile, and a :class:`~repro.telemetry.metrics.MetricsRegistry`
view for export.  Emission to a :class:`~repro.telemetry.sink.MetricsSink`
happens inside :meth:`StreamingCollector.observe_chunk` on a
query-count cadence, so snapshots are deterministic per (workload,
seed).

:class:`StreamingTrace` / :class:`StreamingClusterTrace` expose the
same ``summary()`` / ``tail_latency`` / shed-accounting surface as
:class:`~repro.workloads.trace.PipelineTrace` and
:class:`~repro.cluster.trace.ClusterTrace` — identical keys, values
exact where a counter suffices (means, attainment, goodput, loads,
shed rates) and within sketch tolerance where a percentile is involved
(docs/TELEMETRY.md "Streaming vs. dense").

This module deliberately imports nothing from the rest of ``repro`` —
the run loops depend on telemetry, never the reverse.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from .metrics import MetricsRegistry
from .rollup import DEFAULT_MAX_WINDOWS, WindowedRollup
from .sketch import DEFAULT_COMPRESSION, QuantileSketch

#: Mirrors ``PipelineTrace.SUMMARY_SLO_LEVEL`` (kept local: telemetry
#: must not import the trace types it substitutes for).
SUMMARY_SLO_LEVEL = 0.9

#: Default sink cadence: one snapshot per this many observed queries.
DEFAULT_SINK_INTERVAL = 10_000


class _TierStats:
    """Per-QoS-tier accumulator (docs/QOS.md): a latency sketch plus
    exact counters for served/met/shed/downgraded and offered vs.
    realized SLO value.  One per tier, keyed by tier index."""

    __slots__ = ("name", "latency", "count", "met", "shed",
                 "value_offered", "value_realized", "downgraded")

    def __init__(self, name: str, compression: int = DEFAULT_COMPRESSION):
        self.name = name
        self.latency = QuantileSketch(compression)
        self.count = 0             # queries served in this tier
        self.met = 0               # served within their deadline
        self.shed = 0              # turned away by admission
        self.value_offered = 0.0   # summed value, served + shed
        self.value_realized = 0.0  # summed value of deadline-met queries
        self.downgraded = 0        # routed to a small-model replica


class StreamingCollector:
    """Online accumulator for one pipeline's run.

    The runner feeds it flushed spans of its (bounded, recycled) result
    arrays via :meth:`observe_chunk` and shed arrivals via
    :meth:`observe_shed`; :meth:`finish` freezes it into a
    :class:`StreamingTrace`.  Collectors fold together with
    :meth:`absorb` — per-replica collectors aggregate into fleet
    metrics with counter-exact / sketch-tolerant semantics.
    """

    def __init__(self, slo: float = float("inf"),
                 sink=None,
                 sink_interval: int = DEFAULT_SINK_INTERVAL,
                 compression: int = DEFAULT_COMPRESSION,
                 max_windows: int = DEFAULT_MAX_WINDOWS,
                 namespace: str = "repro",
                 latency_buckets=None):
        self.slo = float(slo)
        self.latency_buckets = latency_buckets
        self.latency = QuantileSketch(compression)
        self.queue_delay = QuantileSketch(compression)
        self.throughput = QuantileSketch(compression)
        self.occupancy = QuantileSketch(compression)  # dispatch sizes
        self.rollup = WindowedRollup(max_windows=max_windows)
        self.num_admitted = 0
        self.num_shed = 0
        self.num_serial = 0
        self.num_slo_met = 0
        self.service_sum = 0.0
        self.steady_thr_sum = 0.0        # throughput sum over pipelined rows
        self.padded_tok_sum = 0.0        # padded tokens executed
        self.actual_tok_sum = 0.0        # useful tokens executed
        self.max_arrival = 0.0
        self.max_completion = 0.0
        self.max_shed_arrival = 0.0
        self.last_queue_depth = 0.0
        self.max_queue_depth = 0.0
        # -- fault tolerance (repro.faults; docs/FAULTS.md) ------------------
        self.num_failed = 0            # admitted queries that never completed
        self.num_retried = 0           # retry attempts made
        self.num_hedged = 0            # hedged dispatches won
        self.wasted_time = 0.0         # cancelled/timed-out occupancy
        self.downtime = 0.0            # crash + breaker-open time
        self.busy_sum = 0.0            # useful occupancy (sum of 1/thr)
        # -- QoS tiers (docs/QOS.md) -----------------------------------------
        self.tier_stats: Optional[List[_TierStats]] = None
        self.track_downgrades = False
        self._compression = compression
        self.sink = sink
        self.sink_interval = max(1, int(sink_interval))
        self.num_emits = 0
        self._since_emit = 0
        self._registry = MetricsRegistry(namespace)
        self._init_registry()

    def configure_tiers(self, names) -> None:
        """Arm per-tier accounting for the given tier names (idempotent
        when re-configured with the same names; tier columns fed to
        :meth:`observe_chunk` / :meth:`observe_shed` require this)."""
        names = tuple(names)
        if self.tier_stats is not None:
            if tuple(t.name for t in self.tier_stats) != names:
                raise ValueError(
                    f"collector already configured with tiers "
                    f"{tuple(t.name for t in self.tier_stats)}, got {names}")
            return
        self.tier_stats = [_TierStats(n, self._compression) for n in names]

    def _init_registry(self) -> None:
        reg = self._registry
        reg.counter("queries_offered_total", "arrivals, admitted plus shed")
        reg.counter("queries_admitted_total", "queries served")
        reg.counter("queries_shed_total", "queries the admission policy "
                                          "turned away")
        reg.counter("queries_serial_total", "exploration-trial queries")
        reg.counter("queries_slo_met_total", "admitted queries within the "
                                             "latency SLO")
        # Summaries share the collector's sketches, so the registry view
        # is always current without copying.
        reg.summary("latency_seconds", "per-query latency").sketch = \
            self.latency
        reg.summary("queue_delay_seconds", "per-query queueing delay"
                    ).sketch = self.queue_delay
        reg.summary("throughput_qps", "per-query pipeline throughput"
                    ).sketch = self.throughput
        reg.summary("batch_occupancy", "dispatch size each query rode in"
                    ).sketch = self.occupancy
        reg.counter("tokens_padded_total", "padded tokens executed "
                                           "(bucket-edge lengths)")
        reg.counter("tokens_actual_total", "useful tokens executed")
        reg.gauge("padded_token_frac", "fraction of executed tokens that "
                                       "were padding waste")
        reg.gauge("queue_depth", "in-system depth at the last arrival")
        reg.gauge("slo_attainment", "fraction of admitted queries within "
                                    "the SLO")
        reg.gauge("shed_rate", "fraction of offered queries shed")
        reg.gauge("offered_qps", "arrival rate so far")
        reg.gauge("achieved_qps", "completion rate so far")
        reg.gauge("goodput_qps", "SLO-met completion rate so far")
        # -- fault tolerance (docs/FAULTS.md) --------------------------------
        reg.counter("queries_failed_total", "admitted queries that "
                                            "exhausted their retry budget")
        reg.counter("queries_retried_total", "retry attempts made")
        reg.counter("queries_hedged_total", "hedged dispatches won")
        reg.counter("wasted_seconds_total", "occupancy charged for work "
                                            "that produced no completion")
        reg.counter("downtime_seconds_total", "replica crash/breaker-open "
                                              "time")
        reg.gauge("availability", "completed / admitted so far")
        # Optional fixed-bucket mirror of the latency summary: external
        # tooling aggregates _bucket series by addition, no sketch merge.
        if self.latency_buckets is not None:
            self._lat_hist = reg.histogram(
                "latency_seconds_hist", "per-query latency (fixed-bucket "
                "histogram mirror)", buckets=self.latency_buckets)
        else:
            self._lat_hist = None

    # -- ingest --------------------------------------------------------------
    def observe_chunk(self, latencies: np.ndarray,
                      service_latencies: np.ndarray,
                      queue_delays: np.ndarray,
                      throughputs: np.ndarray,
                      serial_mask: np.ndarray,
                      arrival_times: np.ndarray,
                      completion_times: np.ndarray,
                      queue_depths: np.ndarray,
                      batch_sizes: Optional[np.ndarray] = None,
                      padded_tokens: Optional[np.ndarray] = None,
                      actual_tokens: Optional[np.ndarray] = None,
                      tier_ids: Optional[np.ndarray] = None,
                      deadlines: Optional[np.ndarray] = None,
                      values: Optional[np.ndarray] = None) -> None:
        """Fold one span of index-aligned per-query rows (the runner's
        flushed arrays; the caller recycles them afterwards).  The
        batching columns are optional — a feeder without them reads as
        all-solo dispatch (occupancy 1) with no token accounting.  The
        QoS columns (tier index, relative deadline, value per query)
        require a prior :meth:`configure_tiers`."""
        n = len(latencies)
        if n == 0:
            return
        self.latency.add(latencies)
        if self._lat_hist is not None:
            self._lat_hist.observe(latencies)
        self.queue_delay.add(queue_delays)
        self.throughput.add(throughputs)
        self.busy_sum += float(np.sum(np.where(
            throughputs > 0, 1.0 / np.maximum(throughputs, 1e-12), 0.0)))
        self.occupancy.add(batch_sizes if batch_sizes is not None
                           else np.ones(n))
        if padded_tokens is not None:
            self.padded_tok_sum += float(padded_tokens.sum())
        if actual_tokens is not None:
            self.actual_tok_sum += float(actual_tokens.sum())
        self.num_admitted += n
        serial = int(np.count_nonzero(serial_mask))
        self.num_serial += serial
        if serial < n:
            self.steady_thr_sum += float(throughputs[~serial_mask].sum())
        self.service_sum += float(service_latencies.sum())
        if math.isfinite(self.slo):
            self.num_slo_met += int(
                np.count_nonzero(latencies <= self.slo))
        else:
            self.num_slo_met += n
        self.max_arrival = max(self.max_arrival, float(arrival_times[-1]))
        self.max_completion = max(self.max_completion,
                                  float(completion_times.max()))
        self.last_queue_depth = float(queue_depths[-1])
        self.max_queue_depth = max(self.max_queue_depth,
                                   float(queue_depths.max()))
        self.rollup.observe_arrivals(arrival_times)
        self.rollup.observe_completions(completion_times, latencies)
        if tier_ids is not None:
            if self.tier_stats is None:
                raise ValueError(
                    "tier columns require configure_tiers() first")
            met_mask = latencies <= deadlines
            for i, ts in enumerate(self.tier_stats):
                m = tier_ids == i
                k = int(np.count_nonzero(m))
                if not k:
                    continue
                ts.latency.add(latencies[m])
                ts.count += k
                ts.met += int(np.count_nonzero(met_mask & m))
                ts.value_offered += float(values[m].sum())
                ts.value_realized += float(values[m & met_mask].sum())
        self._tick_sink(n)

    def observe_shed(self, arrivals, tier: Optional[int] = None,
                     value: float = 1.0) -> None:
        """Record shed arrival time(s) — counters and rollup only, no
        per-query storage.  With ``tier`` the shed also counts against
        that tier's offered value (``value`` is per shed arrival)."""
        times = np.atleast_1d(np.asarray(arrivals, dtype=np.float64))
        if times.size == 0:
            return
        self.num_shed += times.size
        self.max_shed_arrival = max(self.max_shed_arrival,
                                    float(times.max()))
        self.rollup.observe_shed(times)
        if tier is not None:
            if self.tier_stats is None:
                raise ValueError(
                    "tiered sheds require configure_tiers() first")
            ts = self.tier_stats[int(tier)]
            ts.shed += times.size
            ts.value_offered += float(value) * times.size
        self._tick_sink(times.size)

    def note_downgrade(self, tier: int, n: int = 1) -> None:
        """Count ``n`` queries of ``tier`` routed to a small-model
        replica instead of shed (the ``downgrade`` router)."""
        if self.tier_stats is None:
            raise ValueError("downgrades require configure_tiers() first")
        self.track_downgrades = True
        self.tier_stats[int(tier)].downgraded += int(n)

    def _tick_sink(self, n: int) -> None:
        if self.sink is None:
            return
        self._since_emit += n
        if self._since_emit >= self.sink_interval:
            self._since_emit = 0
            self.emit()

    def absorb(self, other: "StreamingCollector") -> "StreamingCollector":
        """Fold another collector's state into this one (fleet
        aggregation); ``other`` is not modified."""
        self.latency.merge(other.latency)
        self.queue_delay.merge(other.queue_delay)
        self.throughput.merge(other.throughput)
        self.occupancy.merge(other.occupancy)
        self.rollup.merge(other.rollup)
        self.num_admitted += other.num_admitted
        self.num_shed += other.num_shed
        self.num_serial += other.num_serial
        self.num_slo_met += other.num_slo_met
        self.service_sum += other.service_sum
        self.steady_thr_sum += other.steady_thr_sum
        self.padded_tok_sum += other.padded_tok_sum
        self.actual_tok_sum += other.actual_tok_sum
        self.max_arrival = max(self.max_arrival, other.max_arrival)
        self.max_completion = max(self.max_completion, other.max_completion)
        self.max_shed_arrival = max(self.max_shed_arrival,
                                    other.max_shed_arrival)
        self.last_queue_depth = other.last_queue_depth
        self.max_queue_depth = max(self.max_queue_depth,
                                   other.max_queue_depth)
        self.num_failed += other.num_failed
        self.num_retried += other.num_retried
        self.num_hedged += other.num_hedged
        self.wasted_time += other.wasted_time
        self.downtime += other.downtime
        self.busy_sum += other.busy_sum
        if self._lat_hist is not None and other._lat_hist is not None:
            self._lat_hist.merge_from(other._lat_hist)
        if other.tier_stats is not None:
            self.configure_tiers([t.name for t in other.tier_stats])
            for mine, theirs in zip(self.tier_stats, other.tier_stats):
                mine.latency.merge(theirs.latency)
                mine.count += theirs.count
                mine.met += theirs.met
                mine.shed += theirs.shed
                mine.value_offered += theirs.value_offered
                mine.value_realized += theirs.value_realized
                mine.downgraded += theirs.downgraded
            self.track_downgrades = (self.track_downgrades
                                     or other.track_downgrades)
        return self

    # -- derived rates --------------------------------------------------------
    @property
    def num_offered(self) -> int:
        return self.num_admitted + self.num_shed

    @property
    def offered_qps(self) -> float:
        # Mirrors the dense definition, including its guard: the span
        # is anchored on *admitted* arrivals, so fewer than two of them
        # reads as NaN even when sheds were recorded.
        if self.num_admitted < 2:
            return math.nan
        span = max(self.max_arrival, self.max_shed_arrival)
        return self.num_offered / span if span > 0 else math.inf

    @property
    def achieved_qps(self) -> float:
        if self.num_admitted < 2:
            return math.nan
        return (self.num_admitted / self.max_completion
                if self.max_completion > 0 else math.inf)

    @property
    def goodput_qps(self) -> float:
        if not math.isfinite(self.slo):
            return self.achieved_qps
        if self.num_admitted < 2:
            return math.nan
        return (self.num_slo_met / self.max_completion
                if self.max_completion > 0 else math.inf)

    @property
    def slo_attainment(self) -> float:
        if not self.num_admitted:
            return math.nan
        if not math.isfinite(self.slo):
            return 1.0
        return self.num_slo_met / self.num_admitted

    @property
    def shed_rate(self) -> float:
        return self.num_shed / self.num_offered if self.num_offered else 0.0

    @property
    def padded_token_frac(self) -> float:
        """Fraction of executed tokens that were padding waste; 0.0
        when the run carried no length information."""
        if self.padded_tok_sum <= 0.0:
            return 0.0
        return 1.0 - self.actual_tok_sum / self.padded_tok_sum

    # -- fault accounting (repro.faults; docs/FAULTS.md) ---------------------
    def note_faults(self, num_failed: int = 0, num_retried: int = 0,
                    num_hedged: int = 0, wasted_time: float = 0.0,
                    downtime: float = 0.0) -> None:
        """Set the run's fault counters to their current absolute
        values (the runner is the source of truth; called on every
        telemetry flush and at :meth:`finish`)."""
        self.num_failed = int(num_failed)
        self.num_retried = int(num_retried)
        self.num_hedged = int(num_hedged)
        self.wasted_time = float(wasted_time)
        self.downtime = float(downtime)

    @property
    def availability(self) -> float:
        """Completed ÷ admitted (sheds excluded — they are an
        admission decision, not a failure)."""
        admitted = self.num_admitted + self.num_failed
        if not admitted:
            return math.nan
        return self.num_admitted / admitted

    @property
    def wasted_work_frac(self) -> float:
        if self.wasted_time <= 0.0:
            return 0.0
        total = self.busy_sum + self.wasted_time
        return self.wasted_time / total if total > 0 else 0.0

    # -- export --------------------------------------------------------------
    def _refresh_registry(self) -> None:
        reg = self._registry
        # Counters are set by value, not by increment: the collector's
        # integer fields are the source of truth and the registry is a
        # read-only view of them (same package; not an external API).
        reg.counter("queries_offered_total")._value = float(self.num_offered)
        reg.counter("queries_admitted_total")._value = float(
            self.num_admitted)
        reg.counter("queries_shed_total")._value = float(self.num_shed)
        reg.counter("queries_serial_total")._value = float(self.num_serial)
        reg.counter("queries_slo_met_total")._value = float(self.num_slo_met)
        reg.counter("tokens_padded_total")._value = self.padded_tok_sum
        reg.counter("tokens_actual_total")._value = self.actual_tok_sum
        reg.gauge("padded_token_frac").set(self.padded_token_frac)
        reg.gauge("queue_depth").set(self.last_queue_depth)
        reg.gauge("slo_attainment").set(self.slo_attainment)
        reg.gauge("shed_rate").set(self.shed_rate)
        reg.gauge("offered_qps").set(self.offered_qps)
        reg.gauge("achieved_qps").set(self.achieved_qps)
        reg.gauge("goodput_qps").set(self.goodput_qps)
        reg.counter("queries_failed_total")._value = float(self.num_failed)
        reg.counter("queries_retried_total")._value = float(self.num_retried)
        reg.counter("queries_hedged_total")._value = float(self.num_hedged)
        reg.counter("wasted_seconds_total")._value = self.wasted_time
        reg.counter("downtime_seconds_total")._value = self.downtime
        reg.gauge("availability").set(self.availability)

    @property
    def registry(self) -> MetricsRegistry:
        """The live metrics view (refreshed on access)."""
        self._refresh_registry()
        return self._registry

    def snapshot(self) -> Dict[str, object]:
        return self.registry.snapshot()

    def prometheus(self) -> str:
        return self.registry.prometheus()

    def emit(self) -> None:
        """Push one snapshot to the sink (no-op without one)."""
        if self.sink is not None:
            self.sink.emit(self.snapshot())
            self.num_emits += 1

    # -- freeze --------------------------------------------------------------
    def finish(self, scheduler: str = "", workload: str = "closed",
               peak_throughput: float = float("nan"),
               admission: str = "none",
               num_rebalances: int = 0, total_trials: int = 0,
               mitigation_lengths: Optional[List[int]] = None,
               final_config: Optional[List[int]] = None,
               num_failed: int = 0, num_retried: int = 0,
               num_hedged: int = 0, wasted_time: float = 0.0,
               downtime: float = 0.0) -> "StreamingTrace":
        """Final sink emission + freeze into a :class:`StreamingTrace`."""
        if num_failed or num_retried or num_hedged or wasted_time or downtime:
            self.note_faults(num_failed, num_retried, num_hedged,
                             wasted_time, downtime)
        self.emit()
        return StreamingTrace(
            scheduler=scheduler, workload=workload, collector=self,
            num_rebalances=num_rebalances, total_trials=total_trials,
            mitigation_lengths=list(mitigation_lengths or []),
            admission=admission, slo_latency=self.slo,
            peak_throughput=peak_throughput, final_config=final_config)


@dataclasses.dataclass
class StreamingTrace:
    """Flat-memory counterpart of
    :class:`~repro.workloads.trace.PipelineTrace`: the same ``summary()``
    keys and shed/goodput surface, computed from a
    :class:`StreamingCollector` instead of dense per-query arrays.

    Exact where counters suffice (means, attainment, goodput, offered /
    achieved load, shed accounting); within sketch tolerance for
    percentiles and ``slo_violations``.  Per-query arrays do not exist:
    code that needs them must run ``trace_mode="dense"``.
    """

    scheduler: str
    workload: str
    collector: StreamingCollector
    num_rebalances: int = 0
    total_trials: int = 0
    mitigation_lengths: List[int] = dataclasses.field(default_factory=list)
    admission: str = "none"
    slo_latency: float = float("inf")
    peak_throughput: float = float("nan")  # stamped post-run by live engine
    final_config: Optional[List[int]] = None

    trace_mode = "streaming"
    SUMMARY_SLO_LEVEL = SUMMARY_SLO_LEVEL

    _SKETCH_FIELDS = {"latencies": "latency", "queue_delays": "queue_delay",
                      "throughputs": "throughput",
                      "batch_sizes": "occupancy"}

    # -- shape / shed accounting --------------------------------------------
    @property
    def num_admitted(self) -> int:
        return self.collector.num_admitted

    @property
    def num_shed(self) -> int:
        return self.collector.num_shed

    @property
    def num_offered(self) -> int:
        return self.collector.num_offered

    @property
    def shed_rate(self) -> float:
        return self.collector.shed_rate

    @property
    def configs(self) -> List[List[int]]:
        """Only the final configuration survives streaming (dense mode
        keeps the full per-query trace)."""
        return [] if self.final_config is None else [self.final_config]

    @property
    def configs_trace(self) -> List[List[int]]:
        return self.configs

    # -- latency / throughput -------------------------------------------------
    def percentile(self, pct: float, field: str = "latencies") -> float:
        """Sketch percentile of a per-query field (``latencies``,
        ``queue_delays`` or ``throughputs``)."""
        try:
            sketch = getattr(self.collector, self._SKETCH_FIELDS[field])
        except KeyError:
            raise ValueError(f"no streaming sketch for field {field!r}; "
                             f"expected one of "
                             f"{sorted(self._SKETCH_FIELDS)}") from None
        return sketch.percentile(pct)

    def tail_latency(self, pct: float = 99.0) -> float:
        return self.collector.latency.percentile(pct)

    @property
    def mean_queue_delay(self) -> float:
        return self.collector.queue_delay.mean

    @property
    def rebalance_fraction(self) -> float:
        c = self.collector
        return (c.num_serial / c.num_admitted if c.num_admitted
                else math.nan)

    @property
    def steady_throughput(self) -> float:
        c = self.collector
        pipelined = c.num_admitted - c.num_serial
        if pipelined:
            return c.steady_thr_sum / pipelined
        return c.throughput.mean

    # -- SLO ------------------------------------------------------------------
    def slo_violations(self, slo_level: float,
                       reference: str = "peak") -> float:
        """Fraction of queries with throughput below ``slo_level`` ×
        reference, via the throughput sketch's CDF."""
        if reference == "peak":
            if not math.isfinite(self.peak_throughput):
                return math.nan
            return self.collector.throughput.cdf(
                slo_level * self.peak_throughput)
        if reference == "resource_constrained":
            raise ValueError(
                "streaming traces carry no per-query resource-constrained "
                "reference; run trace_mode='dense' for rc accounting")
        raise ValueError(reference)

    @property
    def slo_attainment(self) -> float:
        return self.collector.slo_attainment

    @property
    def goodput_qps(self) -> float:
        return self.collector.goodput_qps

    # -- load -----------------------------------------------------------------
    @property
    def offered_load(self) -> float:
        return self.collector.offered_qps

    @property
    def achieved_load(self) -> float:
        return self.collector.achieved_qps

    def load_profile(self, num_windows: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-window offered vs. achieved rates from the rollup.

        Resolution is the rollup's retention, not ``num_windows`` (the
        argument is accepted for drop-in compatibility with the dense
        trace and ignored).
        """
        return self.collector.rollup.rates()

    # -- export ---------------------------------------------------------------
    @property
    def registry(self) -> MetricsRegistry:
        return self.collector.registry

    def snapshot(self) -> Dict[str, object]:
        return self.collector.snapshot()

    def prometheus(self) -> str:
        return self.collector.prometheus()

    # -- the one summary dict -------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Same keys as ``PipelineTrace.summary()``."""
        c = self.collector
        n = c.num_admitted
        peak_known = math.isfinite(self.peak_throughput)
        out = {
            "mean_latency_s": c.latency.mean,
            "p50_latency_s": c.latency.percentile(50),
            "p99_latency_s": c.latency.percentile(99),
            "mean_service_latency_s": (c.service_sum / n if n
                                       else math.nan),
            "mean_queue_delay_s": c.queue_delay.mean,
            "p99_queue_delay_s": c.queue_delay.percentile(99),
            "mean_throughput_qps": c.throughput.mean,
            "steady_throughput_qps": self.steady_throughput,
            "peak_throughput_qps": float(self.peak_throughput),
            "offered_load_qps": c.offered_qps,
            "achieved_load_qps": c.achieved_qps,
            "slo_violations": (self.slo_violations(self.SUMMARY_SLO_LEVEL)
                               if peak_known and n else math.nan),
            "rebalances": self.num_rebalances,
            "serial_frac": self.rebalance_fraction,
            "num_shed": float(c.num_shed),
            "shed_rate": c.shed_rate,
            "goodput_qps": c.goodput_qps,
            "slo_attainment": c.slo_attainment,
            "slo_latency_s": float(self.slo_latency),
            # -- batch occupancy / padding (docs/WORKLOADS.md) --------------
            "mean_batch_occupancy": c.occupancy.mean,
            "p99_batch_occupancy": c.occupancy.percentile(99),
            "padded_token_frac": c.padded_token_frac,
            # -- fault tolerance (docs/FAULTS.md) ----------------------------
            "num_failed": float(c.num_failed),
            "num_retried": float(c.num_retried),
            "num_hedged": float(c.num_hedged),
            "availability": c.availability,
            "wasted_work_frac": c.wasted_work_frac,
            "downtime_s": float(c.downtime),
        }
        if c.tier_stats is not None:
            out.update(self.tier_summary())
        return out

    def tier_summary(self) -> Dict[str, float]:
        """Per-QoS-tier keys (docs/QOS.md), matching the dense
        ``PipelineTrace.tier_summary()`` key set; empty when the run
        had no tiers configured."""
        c = self.collector
        if c.tier_stats is None:
            return {}
        out = {
            "offered_value": sum(t.value_offered for t in c.tier_stats),
            "realized_value": sum(t.value_realized for t in c.tier_stats),
        }
        for t in c.tier_stats:
            offered = t.count + t.shed
            out[f"tier_{t.name}_num"] = float(t.count)
            out[f"tier_{t.name}_shed"] = float(t.shed)
            out[f"tier_{t.name}_p50_latency_s"] = t.latency.percentile(50)
            out[f"tier_{t.name}_p99_latency_s"] = t.latency.percentile(99)
            out[f"tier_{t.name}_deadline_attainment"] = (
                t.met / offered if offered else math.nan)
            if c.track_downgrades:
                out[f"tier_{t.name}_downgraded"] = float(t.downgraded)
        return out

    @classmethod
    def merged(cls, traces: Iterable["StreamingTrace"],
               scheduler: str = "", workload: str = "closed",
               admission: str = "none",
               slo_latency: float = float("inf"),
               peak_throughput: float = float("nan"),
               extra_collector: Optional[StreamingCollector] = None
               ) -> "StreamingTrace":
        """Fold per-replica streaming traces into one fleet trace
        (counter-exact; percentiles within sketch tolerance).
        ``extra_collector`` carries fleet-level-only state — cluster
        sheds that never reached a replica."""
        traces = list(traces)
        coll = StreamingCollector(slo=slo_latency)
        for t in traces:
            coll.absorb(t.collector)
        if extra_collector is not None:
            coll.absorb(extra_collector)
        return cls(
            scheduler=scheduler, workload=workload, collector=coll,
            num_rebalances=sum(t.num_rebalances for t in traces),
            total_trials=sum(t.total_trials for t in traces),
            mitigation_lengths=[m for t in traces
                                for m in t.mitigation_lengths],
            admission=admission, slo_latency=slo_latency,
            peak_throughput=peak_throughput)


@dataclasses.dataclass
class StreamingClusterTrace:
    """Flat-memory counterpart of
    :class:`~repro.cluster.trace.ClusterTrace`: per-replica
    :class:`StreamingTrace` objects plus fleet-level shed/autoscaler
    accounting.  The per-arrival assignment ledger does not exist in
    streaming mode — per-replica shares and the active-replica mean are
    tracked as running counters instead.
    """

    router: str
    workload: str
    scheduler: str
    replicas: List[StreamingTrace]
    #: Offered fleet arrivals (admitted + shed).
    num_queries: int
    admission: str = "none"
    autoscaler: str = "static"
    slo_latency: float = float("inf")
    #: Fleet-level shed accounting (sheds never reach a replica).
    shed_collector: Optional[StreamingCollector] = None
    #: Sum over arrivals of the active-replica count.
    active_sum: float = 0.0

    trace_mode = "streaming"

    # -- shape ---------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    @property
    def num_admitted(self) -> int:
        return sum(t.num_admitted for t in self.replicas)

    @property
    def num_shed(self) -> int:
        return (self.shed_collector.num_shed
                if self.shed_collector is not None else 0)

    @property
    def shed_rate(self) -> float:
        return self.num_shed / self.num_queries if self.num_queries else 0.0

    @property
    def replica_counts(self) -> np.ndarray:
        """Queries served per replica."""
        return np.array([t.num_admitted for t in self.replicas], dtype=int)

    @property
    def mean_active_replicas(self) -> float:
        if not self.num_queries:
            return float(self.num_replicas)
        return self.active_sum / self.num_queries

    # -- fleet metrics --------------------------------------------------------
    @property
    def fleet(self) -> StreamingTrace:
        """The fleet as one StreamingTrace (merged on access, so
        post-run stamping of replica peaks is picked up).

        A heterogeneous fleet has no single interference-free peak, so
        for n > 1 the fleet reference is the served-share-weighted mean
        of the per-replica peaks: the expected peak of the replica a
        uniformly chosen *served* query ran on.  Per-replica SLO
        accounting (:meth:`slo_violations`) still uses each replica's
        own peak exactly."""
        if self.num_replicas == 1:
            peak = self.replicas[0].peak_throughput
        else:
            acc = w = 0.0
            for t in self.replicas:
                if t.num_admitted and math.isfinite(t.peak_throughput):
                    acc += t.num_admitted * t.peak_throughput
                    w += t.num_admitted
            peak = acc / w if w else float("nan")
        return StreamingTrace.merged(
            self.replicas, scheduler=self.scheduler,
            workload=self.workload, admission=self.admission,
            slo_latency=self.slo_latency, peak_throughput=peak,
            extra_collector=self.shed_collector)

    def tail_latency(self, pct: float = 99.0) -> float:
        return self.fleet.tail_latency(pct)

    @property
    def mean_queue_delay(self) -> float:
        return self.fleet.mean_queue_delay

    @property
    def offered_load(self) -> float:
        return self.fleet.offered_load

    @property
    def achieved_load(self) -> float:
        return self.fleet.achieved_load

    def slo_violations(self, slo_level: float) -> float:
        """Admitted-query fraction below ``slo_level`` × *their
        replica's* peak: per-replica sketch CDFs, weighted by served
        share (matches the dense definition within sketch tolerance)."""
        total = self.num_admitted
        if not total:
            return math.nan
        below = 0.0
        for t in self.replicas:
            if not t.num_admitted:
                continue
            below += t.num_admitted * t.collector.throughput.cdf(
                slo_level * t.peak_throughput)
        return below / total

    # -- the one summary dict -------------------------------------------------
    def summary(self) -> Dict[str, float]:
        """Same keys as ``ClusterTrace.summary()``."""
        s = self.fleet.summary()
        peak_known = all(math.isfinite(t.peak_throughput)
                         for t in self.replicas)
        s["slo_violations"] = (
            self.slo_violations(StreamingTrace.SUMMARY_SLO_LEVEL)
            if peak_known else float("nan"))
        s["num_replicas"] = self.num_replicas
        s["router"] = self.router
        counts = self.replica_counts
        s["min_replica_share"] = (float(counts.min())
                                  / max(self.num_admitted, 1))
        s["max_replica_share"] = (float(counts.max())
                                  / max(self.num_admitted, 1))
        s["admission"] = self.admission
        s["autoscaler"] = self.autoscaler
        s["num_shed"] = float(self.num_shed)
        s["shed_rate"] = self.shed_rate
        s["mean_active_replicas"] = self.mean_active_replicas
        return s

    def rows(self) -> List[Dict]:
        """Per-replica + fleet metric rows (same schema as the dense
        ``ClusterTrace.rows()``)."""
        out = []
        for r, t in enumerate(self.replicas):
            row = {"scope": f"replica{r}", "router": self.router,
                   "workload": self.workload, "scheduler": t.scheduler,
                   "queries": int(t.num_admitted)}
            if t.num_admitted:
                row.update(
                    p50_latency=t.percentile(50),
                    p99_latency=t.tail_latency(99),
                    mean_queue_delay=t.mean_queue_delay,
                    steady_throughput=t.steady_throughput,
                    rebalances=t.num_rebalances,
                    total_trials=t.total_trials,
                )
            else:   # a replica the router never picked
                row.update(p50_latency=float("nan"),
                           p99_latency=float("nan"),
                           mean_queue_delay=float("nan"),
                           steady_throughput=float("nan"),
                           rebalances=t.num_rebalances,
                           total_trials=t.total_trials)
            out.append(row)
        s = self.summary()
        out.append({"scope": "fleet", "router": self.router,
                    "workload": self.workload, "scheduler": self.scheduler,
                    "queries": self.num_queries,
                    "p50_latency": s["p50_latency_s"],
                    "p99_latency": s["p99_latency_s"],
                    "mean_queue_delay": s["mean_queue_delay_s"],
                    "steady_throughput": s["steady_throughput_qps"],
                    "rebalances": s["rebalances"],
                    "total_trials": sum(t.total_trials
                                        for t in self.replicas)})
        return out
