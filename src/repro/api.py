"""One unified run declaration: :class:`RunSpec` + :func:`run`.

The repo grew six entry points that each accumulated ~20 near-identical
keyword arguments: :func:`repro.core.simulate`,
:func:`repro.workloads.run_pipeline`,
:meth:`repro.serving.ServingEngine.serve`,
:func:`repro.cluster.run_cluster`, :func:`repro.cluster.simulate_cluster`
and :func:`repro.cluster.serve_cluster`.  ``RunSpec`` factors the shared
surface into frozen sub-specs (workload / admission / batching / faults /
retries / tiers / telemetry / scheduler / mesh — each carrying exactly
the values the existing ``resolve_*`` coercions accept), and
:func:`run` dispatches one declaration to the right driver.  The six
legacy entry points are now thin wrappers that build a ``RunSpec`` and
call :func:`run`, so the spec path and the kwarg path are the *same*
path — bit-identical by construction (tests/test_sharding.py).

New options land in the spec instead of growing six signatures: the
mesh-sliced stage options (docs/SHARDING.md) exist only here
(``RunSpec(mesh=...)``) and on the :class:`~repro.serving.ServingEngine`
constructor for live runs.

Targets are *handles* — a database, an engine, token arrays, callables.
``to_dict()`` serializes everything that isn't a handle (CLI/CI
round-trips); ``from_dict(d, **handles)`` re-attaches them:

    spec = RunSpec(db=db, num_eps=4, num_queries=2000,
                   scheduler=SchedulerSpec(name="odin", alpha=10),
                   workload=WorkloadSpec(name="poisson",
                                         kwargs={"rate": 0.01, "seed": 0}),
                   mesh=MeshSpec(devices=8, coll_cost=0.5))
    trace = run(spec)
    rerun = run(RunSpec.from_dict(spec.to_dict(), db=db))

Dispatch rules (first match wins — docs/API.md):

* ``db`` + ``cluster`` set (any replica count) → fleet simulation
* ``db`` set                                → single-pipeline simulation
* ``replicas`` set (built :class:`Replica`\\ s) → fleet driver
* ``engines`` set                           → live fleet serving
* ``engine`` set                            → live single-engine serving
* ``executor`` + ``runtime`` set            → the raw run loop
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple, Union

from repro.core.events import InterferenceEvent
from repro.core.mesh import MeshSpec, resolve_mesh

__all__ = [
    "AdmissionSpec", "BatchingSpec", "ClusterSpec", "FaultsSpec",
    "MeshSpec", "RetriesSpec", "RunSpec", "SchedulerSpec",
    "TelemetrySpec", "TiersSpec", "WorkloadSpec", "run",
]


def _asdict_clean(obj) -> dict:
    """Sub-spec → dict with default-valued and handle fields dropped."""
    out = {}
    for f in dataclasses.fields(obj):
        if f.metadata.get("handle"):
            continue
        v = getattr(obj, f.name)
        default = (f.default if f.default is not dataclasses.MISSING
                   else None)
        if v != default:
            out[f.name] = v
    return out


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    """Scheduling policy (``repro.schedulers`` registry name or a
    constructed :class:`~repro.schedulers.base.SchedulerPolicy`)."""
    name: Any = "odin"
    alpha: int = 10
    rel_threshold: Optional[float] = None
    initial_config: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.initial_config is not None:
            object.__setattr__(self, "initial_config",
                               tuple(int(c) for c in self.initial_config))


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Arrival process (``repro.workloads`` registry name / instance)."""
    name: Any = "closed"
    kwargs: Optional[dict] = None


@dataclasses.dataclass(frozen=True)
class AdmissionSpec:
    """Admission policy (``repro.control`` registry name / instance)."""
    name: Any = None
    kwargs: Optional[dict] = None


@dataclasses.dataclass(frozen=True)
class BatchingSpec:
    """Chunking, batched dispatch and length buckets
    (docs/WORKLOADS.md).  ``former`` is a pre-built
    :class:`~repro.workloads.batching.BatchFormer` handle (the raw
    run-loop path); everything else is declarative."""
    mode: Any = None                   # None | "drain" | "continuous"
    max_batch: Optional[int] = None    # None = target's own default
    buckets: Any = None
    explore_in_batch: bool = False
    chunking: bool = True
    max_chunk: Optional[int] = None
    lengths: Any = None
    lengths_kwargs: Optional[dict] = None
    batch_overhead: float = 0.0
    length_ref: Optional[float] = None
    former: Any = dataclasses.field(default=None, compare=False,
                                    metadata={"handle": True})


@dataclasses.dataclass(frozen=True)
class FaultsSpec:
    """Fault injection + recovery routing (docs/FAULTS.md)."""
    plan: Any = None                   # FaultPlan | spec string | None
    hedge_after: Optional[float] = None
    health_kwargs: Optional[dict] = None
    when_all_unhealthy: str = "wait"


@dataclasses.dataclass(frozen=True)
class RetriesSpec:
    """Retry budget (``resolve_retries``: RetrySpec | int | dict)."""
    policy: Any = None


@dataclasses.dataclass(frozen=True)
class TiersSpec:
    """QoS tier stamping (``resolve_tiers``; docs/QOS.md)."""
    spec: Any = None
    kwargs: Optional[dict] = None


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Trace surface selection (docs/TELEMETRY.md).  ``metrics_sink``
    is a live object (handle) — excluded from ``to_dict``."""
    trace_mode: str = "dense"
    sink_interval: Optional[int] = None
    metrics_sink: Any = dataclasses.field(default=None, compare=False,
                                          metadata={"handle": True})


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Fleet shape + routing (docs/CLUSTER.md).  ``databases`` is a
    per-replica :class:`~repro.core.LayerDatabase` handle list
    (heterogeneous sim fleets)."""
    num_replicas: int = 1
    router: Any = "round_robin"
    router_kwargs: Optional[dict] = None
    autoscaler: Any = None
    autoscaler_kwargs: Optional[dict] = None
    max_batch: int = 1
    pools: Optional[Tuple[str, ...]] = None
    databases: Any = dataclasses.field(default=None, compare=False,
                                       metadata={"handle": True})

    def __post_init__(self):
        if self.pools is not None:
            object.__setattr__(self, "pools", tuple(self.pools))


_SUBSPECS = {
    "scheduler": SchedulerSpec,
    "workload": WorkloadSpec,
    "admission": AdmissionSpec,
    "batching": BatchingSpec,
    "faults": FaultsSpec,
    "retries": RetriesSpec,
    "tiers": TiersSpec,
    "telemetry": TelemetrySpec,
    "cluster": ClusterSpec,
}

#: RunSpec fields that are live objects, never serialized.
_HANDLES = ("db", "engine", "engines", "replicas", "executor", "runtime",
            "queries", "schedule")


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One serving run, declaratively.  See the module docstring for
    dispatch rules and docs/API.md for the kwargs → spec migration
    table.  Sub-spec fields also accept plain dicts (coerced on
    construction), so ``RunSpec(db=db, scheduler={"name": "lls"})``
    round-trips through ``to_dict``/``from_dict`` unchanged."""

    # -- target handles (exactly one dispatch group) ----------------------
    db: Any = None                     # LayerDatabase → simulator
    engine: Any = None                 # ServingEngine → live serving
    engines: Any = None                # Sequence[ServingEngine] → fleet
    replicas: Any = None               # Sequence[Replica] → fleet driver
    executor: Any = None               # QueryExecutor → raw run loop
    runtime: Any = None                # RebalanceRuntime (with executor)
    queries: Any = None                # token arrays (live targets)
    schedule: Any = None               # slowdown schedule(s) (live)

    # -- run shape --------------------------------------------------------
    num_eps: int = 4
    num_queries: Optional[int] = None  # None = len(queries) (live)
    seed: int = 0
    peak_throughput: float = float("nan")   # raw run-loop reference

    # -- interference (simulator targets) ---------------------------------
    events: Any = None                 # Sequence[InterferenceEvent]|None
    freq_period: int = 10
    duration: int = 10
    events_time_indexed: bool = False

    # -- sub-specs --------------------------------------------------------
    scheduler: SchedulerSpec = SchedulerSpec()
    workload: WorkloadSpec = WorkloadSpec()
    admission: AdmissionSpec = AdmissionSpec()
    batching: BatchingSpec = BatchingSpec()
    faults: FaultsSpec = FaultsSpec()
    retries: RetriesSpec = RetriesSpec()
    tiers: TiersSpec = TiersSpec()
    telemetry: TelemetrySpec = TelemetrySpec()
    #: ``None`` = single-pipeline target.  Any :class:`ClusterSpec` —
    #: including ``num_replicas=1`` — selects the fleet drivers and a
    #: :class:`~repro.cluster.ClusterTrace` result (an n=1 fleet is the
    #: bit-identical reduction, tests/test_cluster.py, but a *fleet*
    #: nonetheless).
    cluster: Optional[ClusterSpec] = None
    #: Mesh-sliced stages (docs/SHARDING.md): ``None`` (unsharded — the
    #: bit-identical default), a device count, a kwargs dict, or a
    #: :class:`~repro.core.mesh.MeshSpec`.  Simulator targets only; live
    #: engines take their mesh at construction
    #: (``ServingEngine(mesh=...)``).
    mesh: Union[None, int, dict, MeshSpec] = None

    def __post_init__(self):
        for name, cls in _SUBSPECS.items():
            v = getattr(self, name)
            if v is None and name == "cluster":
                continue
            if isinstance(v, dict):
                object.__setattr__(self, name, cls(**v))
            elif not isinstance(v, cls):
                raise TypeError(f"RunSpec.{name} must be a {cls.__name__}"
                                f" or a dict, got {type(v).__name__}")
        object.__setattr__(self, "mesh", resolve_mesh(self.mesh))
        if self.events is not None:
            object.__setattr__(self, "events", tuple(
                ev if isinstance(ev, InterferenceEvent)
                else InterferenceEvent(**ev)
                for ev in self.events))

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-ready dict of every non-handle, non-default field.
        Handles (``db``, ``engine``, ``queries``, sinks, formers, ...)
        are dropped — re-supply them to :meth:`from_dict`."""
        out: dict = {}
        for f in dataclasses.fields(self):
            if f.name in _HANDLES:
                continue
            v = getattr(self, f.name)
            if f.name in _SUBSPECS:
                if v is None:
                    continue
                d = _asdict_clean(v)
                if d or f.name == "cluster":
                    out[f.name] = d
            elif f.name == "mesh":
                if v is not None:
                    out["mesh"] = v.to_dict()
            elif f.name == "events":
                if v is not None:
                    out["events"] = [dataclasses.asdict(ev) for ev in v]
            elif f.name == "peak_throughput":
                if v == v:          # NaN-safe default check
                    out[f.name] = v
            elif v != f.default:
                out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: dict, **handles) -> "RunSpec":
        """Rebuild a spec from :meth:`to_dict` output; keyword
        arguments re-attach the live handles (``db=...``,
        ``engine=...``, ``queries=...``, ...)."""
        return cls(**{**d, **handles})

    def replace(self, **changes) -> "RunSpec":
        """Functional update (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)


def _count(spec: RunSpec) -> int:
    if spec.num_queries is not None:
        return int(spec.num_queries)
    if spec.queries is not None:
        return len(spec.queries)
    raise ValueError("RunSpec needs num_queries (or queries to count)")


def run(spec: RunSpec):
    """Execute one :class:`RunSpec`; returns the target's trace surface
    (:class:`~repro.workloads.PipelineTrace`,
    :class:`~repro.cluster.ClusterTrace` or a streaming variant).
    Imports lazily so declaring specs never pulls in JAX."""
    if not isinstance(spec, RunSpec):
        raise TypeError(f"run() takes a RunSpec, got "
                        f"{type(spec).__name__}")
    sch, wl, adm = spec.scheduler, spec.workload, spec.admission
    bat, tel, cl = spec.batching, spec.telemetry, spec.cluster
    common = dict(workload=wl.name, workload_kwargs=wl.kwargs,
                  admission=adm.name, admission_kwargs=adm.kwargs,
                  trace_mode=tel.trace_mode,
                  metrics_sink=tel.metrics_sink,
                  sink_interval=tel.sink_interval,
                  faults=spec.faults.plan, retries=spec.retries.policy,
                  tiers=spec.tiers.spec, tiers_kwargs=spec.tiers.kwargs)

    def _fleet(cl: ClusterSpec) -> dict:
        return dict(router=cl.router, router_kwargs=cl.router_kwargs,
                    autoscaler=cl.autoscaler,
                    autoscaler_kwargs=cl.autoscaler_kwargs,
                    max_batch=cl.max_batch,
                    hedge_after=spec.faults.hedge_after,
                    health_kwargs=spec.faults.health_kwargs,
                    when_all_unhealthy=spec.faults.when_all_unhealthy,
                    pools=(list(cl.pools) if cl.pools is not None
                           else None))

    if spec.db is not None:
        if cl is not None:
            if spec.mesh is not None:
                raise NotImplementedError(
                    "mesh-sliced stages are single-pipeline this "
                    "release (ROADMAP: cluster mesh)")
            from repro.cluster.sim import _simulate_cluster_impl
            return _simulate_cluster_impl(
                spec.db, spec.num_eps, cl.num_replicas,
                scheduler=sch.name, alpha=sch.alpha,
                rel_threshold=sch.rel_threshold,
                initial_config=(list(sch.initial_config)
                                if sch.initial_config is not None
                                else None),
                num_queries=_count(spec), events=spec.events,
                events_time_indexed=spec.events_time_indexed,
                databases=cl.databases, **common, **_fleet(cl))
        from repro.core.simulator import _simulate_impl
        if spec.faults.hedge_after is not None:
            raise ValueError("hedging needs a fleet target "
                             "(set RunSpec.cluster)")
        return _simulate_impl(
            spec.db, spec.num_eps, scheduler=sch.name, alpha=sch.alpha,
            rel_threshold=sch.rel_threshold,
            initial_config=(list(sch.initial_config)
                            if sch.initial_config is not None
                            else None),
            num_queries=_count(spec), freq_period=spec.freq_period,
            duration=spec.duration, seed=spec.seed, events=spec.events,
            events_time_indexed=spec.events_time_indexed,
            chunking=bat.chunking, max_chunk=bat.max_chunk,
            batching=bat.mode,
            max_batch=(8 if bat.max_batch is None else bat.max_batch),
            buckets=bat.buckets, explore_in_batch=bat.explore_in_batch,
            lengths=bat.lengths, lengths_kwargs=bat.lengths_kwargs,
            batch_overhead=bat.batch_overhead,
            length_ref=bat.length_ref, mesh=spec.mesh, **common)

    if spec.mesh is not None:
        raise ValueError("RunSpec.mesh configures simulator targets; "
                         "live engines take their mesh at construction "
                         "(ServingEngine(mesh=...), docs/SHARDING.md)")

    if spec.replicas is not None:
        if spec.faults.plan is not None:
            raise ValueError("with a replicas target, fault plans are "
                             "attached per-Replica (Replica(faults=...)),"
                             " not on the RunSpec")
        from repro.cluster.cluster import _run_cluster_impl
        fl = _fleet(cl if cl is not None else ClusterSpec())
        fl.pop("pools")
        return _run_cluster_impl(
            spec.replicas, _count(spec), workload=wl.name,
            workload_kwargs=wl.kwargs, scheduler_name=_name_of(sch.name),
            admission=adm.name, admission_kwargs=adm.kwargs,
            trace_mode=tel.trace_mode, metrics_sink=tel.metrics_sink,
            sink_interval=tel.sink_interval,
            retries=spec.retries.policy,
            tiers=spec.tiers.spec, tiers_kwargs=spec.tiers.kwargs, **fl)

    if spec.engines is not None:
        from repro.cluster.live import _serve_cluster_impl
        return _serve_cluster_impl(
            spec.engines, spec.queries, spec.schedule, **common,
            **_fleet(cl if cl is not None else ClusterSpec()))

    if spec.engine is not None:
        for bad, msg in ((spec.faults.hedge_after, "hedging"),
                         (cl, "a ClusterSpec")):
            if bad is not None:
                raise ValueError(f"{msg} needs a fleet target "
                                 "(engines=..., not engine=...)")
        return spec.engine._serve_impl(
            spec.queries, spec.schedule,
            max_batch=(1 if bat.max_batch is None else bat.max_batch),
            batching=bat.mode, buckets=bat.buckets,
            explore_in_batch=bat.explore_in_batch, **common)

    if spec.executor is not None and spec.runtime is not None:
        from repro.workloads.runner import _run_pipeline_impl
        return _run_pipeline_impl(
            spec.executor, spec.runtime, _count(spec),
            scheduler_name=_name_of(sch.name),
            peak_throughput=spec.peak_throughput,
            chunking=bat.chunking, max_chunk=bat.max_chunk,
            former=bat.former, lengths=bat.lengths,
            lengths_kwargs=bat.lengths_kwargs, **common)

    raise ValueError(
        "RunSpec names no target: set db (simulate), engine/engines "
        "(live), replicas (fleet driver), or executor + runtime "
        "(raw run loop)")


def _name_of(scheduler) -> str:
    """Trace label for the scheduler field of handle-target specs."""
    if isinstance(scheduler, str):
        return scheduler
    return getattr(scheduler, "name", type(scheduler).__name__)
