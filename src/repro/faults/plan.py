"""Deterministic fault plans: what breaks, where, and when.

A :class:`FaultPlan` generalizes the interference timeline
(:mod:`repro.core.events`) into a *fault* timeline.  Interference is a
soft fault — a stage slows down and the scheduler rebalances around it;
a fault plan adds the hard kinds a production fleet sees:

``crash``
    The replica is down for the whole window (its recovery delay *is*
    the window duration); dispatches raise
    :class:`~repro.util.errors.ReplicaUnavailableError` and the replica
    restarts cold at the window end (see ``Replica.on_recover`` /
    ``warm_buckets`` for the re-warm hook).
``hang``
    Dispatches starting inside the window stall for ``stall`` seconds
    of extra occupancy.  With a per-dispatch timeout configured
    (:class:`~repro.faults.RetrySpec`), a stall exceeding the timeout
    raises :class:`~repro.util.errors.DispatchTimeoutError` instead and
    the timeout is charged as wasted work.
``slowdown``
    Multiplicative stage-time inflation (``factor``) beyond the
    interference model — service latency scales up, throughput down.
``flaky``
    Each execution attempt inside the window raises
    :class:`~repro.util.errors.TransientQueryError` with probability
    ``p``, drawn deterministically from ``(seed, replica, query,
    attempt)`` so retries re-draw but reruns are bit-identical.

Windows are half-open ``[start, start + duration)`` on the same clock
axis the interference timeline uses: the query index by default, or
the arrival wall-clock when ``time_indexed=True`` (docs/CLUSTER.md).
Like :func:`~repro.core.events.events_for_replica`, ``replica=None``
hits every replica and :meth:`FaultPlan.for_replica` selects one
replica's slice of a fleet plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

FAULT_KINDS = ("crash", "hang", "slowdown", "flaky")


@dataclass(frozen=True)
class FaultEvent:
    """One fault window.  ``start``/``duration`` are on the plan's
    clock axis; ``replica=None`` applies to every replica."""
    kind: str
    start: float
    duration: float
    replica: Optional[int] = None
    factor: float = 2.0        # slowdown: stage-time multiplier
    p: float = 0.5             # flaky: per-attempt failure probability
    stall: float = 0.0         # hang: extra seconds per dispatch

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.duration <= 0:
            raise ValueError(f"fault duration must be > 0, "
                             f"got {self.duration}")
        if self.kind == "slowdown" and self.factor <= 0:
            raise ValueError("slowdown factor must be > 0")
        if self.kind == "flaky" and not 0.0 <= self.p <= 1.0:
            raise ValueError("flaky probability must be in [0, 1]")
        if self.kind == "hang" and self.stall < 0:
            raise ValueError("hang stall must be >= 0")

    @property
    def end(self) -> float:
        return self.start + self.duration

    def active_at(self, clock: float) -> bool:
        return self.start <= clock < self.end


@dataclass
class FaultPlan:
    """A seeded, deterministic set of fault windows.

    ``time_indexed`` selects the clock axis (arrival seconds vs. query
    index), mirroring :class:`~repro.core.events.EventTimeline`.
    """
    events: List[FaultEvent] = field(default_factory=list)
    seed: int = 0
    time_indexed: bool = False

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: (e.start, e.end))

    def __bool__(self) -> bool:
        return bool(self.events)

    def for_replica(self, replica: int) -> "FaultPlan":
        """The slice of this plan one replica experiences (fleet-wide
        events with ``replica=None`` included)."""
        return FaultPlan(events=[e for e in self.events
                                 if e.replica is None
                                 or e.replica == replica],
                         seed=self.seed, time_indexed=self.time_indexed)

    def downtime_until(self, clock_end: float) -> float:
        """Total crash downtime accumulated by ``clock_end`` (clipped
        window overlap, in the plan's clock units)."""
        total = 0.0
        for e in self.events:
            if e.kind == "crash":
                total += max(0.0, min(e.end, clock_end) - e.start)
        return total


def parse_fault_spec(spec: str, seed: int = 0,
                     time_indexed: bool = False) -> FaultPlan:
    """Parse a compact CLI fault spec into a :class:`FaultPlan`.

    Grammar (comma-separated windows)::

        kind@start+duration[:key=value...]

    with keys ``r`` (replica), ``f`` (slowdown factor), ``p`` (flaky
    probability), ``s`` (hang stall seconds).  Examples::

        crash@200+100:r=0
        flaky@0+1000:p=0.05,slowdown@300+50:f=2.5
        hang@400+20:s=0.5:r=1
    """
    events = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        head = fields[0]
        try:
            kind, when = head.split("@")
            start_s, dur_s = when.split("+")
            ev = dict(kind=kind.strip(), start=float(start_s),
                      duration=float(dur_s))
        except ValueError:
            raise ValueError(
                f"bad fault window {part!r}; expected "
                "'kind@start+duration[:key=value...]'") from None
        for kv in fields[1:]:
            try:
                k, v = kv.split("=")
            except ValueError:
                raise ValueError(f"bad fault option {kv!r} in {part!r}; "
                                 "expected 'key=value'") from None
            k = k.strip()
            if k == "r":
                ev["replica"] = int(v)
            elif k == "f":
                ev["factor"] = float(v)
            elif k == "p":
                ev["p"] = float(v)
            elif k == "s":
                ev["stall"] = float(v)
            else:
                raise ValueError(f"unknown fault option {k!r} in {part!r}; "
                                 "expected r/f/p/s")
        events.append(FaultEvent(**ev))
    return FaultPlan(events=events, seed=seed, time_indexed=time_indexed)


def periodic_crashes(horizon: float, period: float, duration: float,
                     num_replicas: int = 1, start: Optional[float] = None,
                     seed: int = 0,
                     time_indexed: bool = False) -> FaultPlan:
    """Replica-churn plan: every ``period`` clock units one replica
    (rotating round-robin) crashes for ``duration``.  The soak
    scenario's churn generator — fully deterministic."""
    events = []
    t = period if start is None else start
    r = 0
    while t < horizon:
        events.append(FaultEvent("crash", start=t, duration=duration,
                                 replica=r % num_replicas))
        r += 1
        t += period
    return FaultPlan(events=events, seed=seed, time_indexed=time_indexed)


def resolve_faults(faults, seed: int = 0,
                   time_indexed: bool = False) -> Optional[FaultPlan]:
    """None / spec string / event list / FaultPlan -> FaultPlan."""
    if faults is None:
        return None
    if isinstance(faults, FaultPlan):
        return faults
    if isinstance(faults, str):
        return parse_fault_spec(faults, seed=seed, time_indexed=time_indexed)
    if isinstance(faults, (list, tuple)):
        events = []
        for e in faults:
            if isinstance(e, FaultEvent):
                events.append(e)
            elif isinstance(e, str):
                events.extend(parse_fault_spec(e).events)
            else:
                events.append(FaultEvent(*e))
        return FaultPlan(events=events, seed=seed,
                         time_indexed=time_indexed)
    raise TypeError(f"cannot resolve a fault plan from "
                    f"{type(faults).__name__}")


__all__ = ["FAULT_KINDS", "FaultEvent", "FaultPlan", "parse_fault_spec",
           "periodic_crashes", "resolve_faults"]
