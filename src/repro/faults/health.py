"""Per-replica health tracking: a circuit breaker for the router.

The :class:`~repro.cluster.Cluster` keeps one :class:`HealthTracker`
over its fleet.  Replicas start *closed* (healthy).  ``failure_streak``
consecutive dispatch failures — or a single
:class:`~repro.util.errors.ReplicaUnavailableError` with a known
recovery time — *open* the breaker: routers stop seeing the replica
until the cooldown expires.  The first dispatch after expiry is a
*half-open* probe; its success closes the breaker (and fires the
replica's re-warm hook first, off the timed path), its failure
re-opens it for another cooldown.

Everything is driven by the serving loop's deterministic clock, so
breaker transitions — and therefore routing — are bit-identical across
runs.
"""
from __future__ import annotations

from typing import List

_INF = float("inf")

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class HealthTracker:
    """Circuit breaker over ``num_replicas`` replicas.

    ``failure_threshold`` — consecutive failures that open the breaker.
    ``cooldown`` — seconds (serving clock) an open breaker holds before
    allowing a half-open probe.
    """

    def __init__(self, num_replicas: int, failure_threshold: int = 3,
                 cooldown: float = 1.0):
        if num_replicas < 1:
            raise ValueError("need at least one replica")
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if cooldown < 0:
            raise ValueError("cooldown must be >= 0")
        self.failure_threshold = int(failure_threshold)
        self.cooldown = float(cooldown)
        n = int(num_replicas)
        self._state: List[str] = [CLOSED] * n
        self._streak = [0] * n
        self._open_until = [-_INF] * n
        self._down_since = [0.0] * n
        #: set when a replica re-opens for probing: the serving loop
        #: fires ``Replica.on_recover`` (re-warm) before the probe.
        self._needs_rewarm = [False] * n
        self.downtime = [0.0] * n      # accumulated open time per replica

    @property
    def num_replicas(self) -> int:
        return len(self._state)

    def state(self, r: int) -> str:
        return self._state[r]

    def healthy(self, r: int, now: float) -> bool:
        """May replica ``r`` take traffic at ``now``?  Transitions an
        expired open breaker to half-open (probe allowed)."""
        st = self._state[r]
        if st == CLOSED:
            return True
        if st == OPEN:
            if now < self._open_until[r]:
                return False
            self._state[r] = HALF_OPEN
            self._needs_rewarm[r] = True
            return True
        return True                    # half-open: probe in flight

    def ready_at(self, r: int) -> float:
        """Earliest clock at which ``r`` could take a probe (now-ish
        for closed/half-open replicas)."""
        return self._open_until[r] if self._state[r] == OPEN else -_INF

    def take_rewarm(self, r: int) -> bool:
        """True exactly once per open->probe transition: the caller
        should re-warm the replica before its probe dispatch."""
        if self._needs_rewarm[r]:
            self._needs_rewarm[r] = False
            return True
        return False

    def record_success(self, r: int, now: float) -> None:
        if self._state[r] != CLOSED:
            self.downtime[r] += max(0.0, now - self._down_since[r])
        self._state[r] = CLOSED
        self._streak[r] = 0
        self._needs_rewarm[r] = False

    def record_failure(self, r: int, now: float,
                       until: float = float("nan")) -> None:
        """One dispatch failure on ``r`` at ``now``.  ``until`` — a
        known recovery time (crash faults report theirs); the breaker
        holds until ``max(now + cooldown, until)`` when finite."""
        self._streak[r] += 1
        was_up = self._state[r] == CLOSED
        opens = (self._state[r] == HALF_OPEN           # failed probe
                 or self._streak[r] >= self.failure_threshold
                 or until == until)                    # known-down (non-NaN)
        if not opens:
            return
        hold = now + self.cooldown
        if until == until:             # finite recovery time known
            hold = max(hold, until)
        if was_up or self._state[r] == HALF_OPEN:
            if was_up:
                self._down_since[r] = now
            # A failed probe extends the *same* outage: down_since keeps
            # the original open instant.
        self._state[r] = OPEN
        self._open_until[r] = max(self._open_until[r], hold)
        self._needs_rewarm[r] = False

    def finalize(self, now: float) -> List[float]:
        """Close out still-open outages at ``now`` (end of a serving
        window); returns the per-replica downtime list."""
        for r in range(len(self._state)):
            if self._state[r] != CLOSED:
                self.downtime[r] += max(0.0, now - self._down_since[r])
                self._down_since[r] = now
        return self.downtime


__all__ = ["HealthTracker", "CLOSED", "OPEN", "HALF_OPEN"]
