"""Fault injection and recovery machinery (docs/FAULTS.md).

The fault model generalizes the interference timeline into hard
failures — ``crash`` / ``hang`` / ``slowdown`` / ``flaky`` — realized
deterministically by :class:`FaultingExecutor` over any query
executor (simulator or live engine).  Recovery lives in the serving
loops: :func:`~repro.workloads.run_pipeline` retries transient
failures under a :class:`RetrySpec` budget; the fleet layer
(:func:`~repro.cluster.run_cluster`) adds health-aware routing via
:class:`HealthTracker` circuit breakers, tail-latency hedging, and
graceful re-warm on recovery.
"""
from repro.faults.health import HealthTracker  # noqa: F401
from repro.faults.inject import FaultingExecutor, FaultInjector  # noqa: F401
from repro.faults.plan import (  # noqa: F401
    FAULT_KINDS,
    FaultEvent,
    FaultPlan,
    parse_fault_spec,
    periodic_crashes,
    resolve_faults,
)
from repro.faults.retry import RetrySpec, resolve_retries  # noqa: F401

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultPlan",
    "FaultingExecutor",
    "FaultInjector",
    "HealthTracker",
    "RetrySpec",
    "parse_fault_spec",
    "periodic_crashes",
    "resolve_faults",
    "resolve_retries",
]
