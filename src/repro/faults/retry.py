"""Retry budgets: exponential backoff with deterministic jitter.

One :class:`RetrySpec` parameterizes the whole recovery surface —
per-query retry budget, backoff schedule, and the per-dispatch timeout
that converts ``hang`` faults into retryable
:class:`~repro.util.errors.DispatchTimeoutError` failures.  Jitter is
drawn from ``(seed, query, attempt)`` so two runs of the same plan
produce bit-identical schedules while distinct queries still
de-synchronize their retries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

#: Domain-separation salt for the jitter stream (keeps it independent
#: of the flaky-fault draw stream, which salts differently).
_JITTER_SALT = 0x9e77


@dataclass(frozen=True)
class RetrySpec:
    """Budget + schedule for requeue-on-failure.

    ``delay(q, attempt)`` returns the backoff before retry
    ``attempt`` (0-based) of query ``q``:
    ``backoff * multiplier**attempt * (1 + jitter * u)`` with ``u``
    uniform in ``[0, 1)`` drawn deterministically from
    ``(seed, q, attempt)``.

    ``timeout`` is the per-dispatch stall bound: a ``hang`` fault whose
    stall exceeds it fails the dispatch (charging ``timeout`` as wasted
    occupancy) instead of inflating its latency.  ``None`` disables
    timeouts — hangs then surface as latency.

    ``batch_policy`` governs what a failure inside a *rebatched* fleet
    flush (``max_batch > 1``, docs/CLUSTER.md) takes down with it.
    Queries already completed before the failing dispatch always keep
    their rows; the policy decides the fate of the failing query and
    the buffered tail behind it:

    * ``"resplit"`` (default) — the batch dissolves: the failing query
      and the untouched tail each retry through the single-query path
      (per-query budget, backoff, healthy re-route).
    * ``"subset"`` — only the failing query leaves the batch (it
      retries as a single); the untouched tail re-flushes as a batch.
    * ``"all"`` — fail-whole-batch: the failing query and the tail
      share one attempt budget and re-flush together on a healthy
      replica after the backoff; exhausting the budget fails them all.
    """
    max_retries: int = 3
    backoff: float = 0.05
    multiplier: float = 2.0
    jitter: float = 0.0
    seed: int = 0
    timeout: Optional[float] = None
    batch_policy: str = "resplit"

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff < 0 or self.multiplier <= 0 or self.jitter < 0:
            raise ValueError("backoff >= 0, multiplier > 0, jitter >= 0 "
                             "required")
        if self.timeout is not None and self.timeout <= 0:
            raise ValueError("timeout must be > 0 (or None)")
        if self.batch_policy not in ("all", "subset", "resplit"):
            raise ValueError(f"batch_policy must be 'all', 'subset' or "
                             f"'resplit', got {self.batch_policy!r}")

    def delay(self, query: int, attempt: int) -> float:
        base = self.backoff * self.multiplier ** attempt
        if self.jitter <= 0.0 or base <= 0.0:
            return base
        u = np.random.default_rng(
            (self.seed, _JITTER_SALT, int(query), int(attempt))).random()
        return base * (1.0 + self.jitter * u)


def resolve_retries(retries) -> Optional[RetrySpec]:
    """None / int budget / kwargs dict / RetrySpec -> RetrySpec."""
    if retries is None:
        return None
    if isinstance(retries, RetrySpec):
        return retries
    if isinstance(retries, bool):
        raise TypeError("retries must be an int budget, a kwargs dict or "
                        "a RetrySpec, not a bool")
    if isinstance(retries, int):
        return RetrySpec(max_retries=retries)
    if isinstance(retries, dict):
        return RetrySpec(**retries)
    raise TypeError(f"cannot resolve a RetrySpec from "
                    f"{type(retries).__name__}")


__all__ = ["RetrySpec", "resolve_retries"]
