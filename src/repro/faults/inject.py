"""Fault injection: wrap any :class:`~repro.workloads.base.QueryExecutor`.

:class:`FaultingExecutor` sits between the event loop and the real
executor (simulator or live engine) and realizes a
:class:`~repro.faults.plan.FaultPlan` deterministically:

* ``crash`` / ``flaky`` / timed-out ``hang`` raise the typed errors
  from :mod:`repro.util.errors` *before* the inner executor runs — the
  runner's retry machinery (or the cluster's) requeues or fails the
  query.
* ``slowdown`` and sub-timeout ``hang`` inflate the inner record's
  service latency / occupancy in place.

Chunk safety: the wrapper's ``steady_horizon`` cuts every chunk at
fault-window edges and forces single-query execution *inside* windows,
so the batch-granular fast path never spans a query whose outcome
differs from the scalar tick — chunked == scalar bit-identity holds
with faults active (gated by ``tests/test_faults.py``).

Formed-dispatch batching (``BatchFormer``) does not compose with fault
injection — a multi-member dispatch has no per-query failure boundary;
``configure_batching`` refuses a former explicitly.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.faults.plan import FaultPlan
from repro.util.errors import (DispatchTimeoutError, ReplicaUnavailableError,
                               TransientQueryError)
from repro.workloads.base import BatchRecord, QueryRecord

#: Domain-separation salt for the flaky draw stream (distinct from the
#: retry-jitter salt in :mod:`repro.faults.retry`).
_FLAKY_SALT = 0x1f1a

_BIG = 2 ** 62   # finite "no fault ahead" horizon (int() safe)


class FaultInjector:
    """One replica's runtime view of a fault plan.

    Stateless apart from the per-query failed-attempt counts that feed
    the flaky draw (cleared on success), so reruns are bit-identical.
    """

    def __init__(self, plan: FaultPlan, replica: int = 0,
                 timeout: Optional[float] = None):
        self.plan = plan
        self.replica = int(replica)
        self.timeout = timeout
        self.events = [e for e in plan.events
                       if e.replica is None or e.replica == self.replica]
        self._attempts = {}

    def _active(self, clock: float) -> List:
        out = []
        for e in self.events:          # sorted by start
            if e.start > clock:
                break
            if clock < e.end:
                out.append(e)
        return out

    def in_window(self, clock: float) -> bool:
        for e in self.events:
            if e.start > clock:
                return False
            if clock < e.end:
                return True
        return False

    def next_start(self, clock: float) -> float:
        for e in self.events:
            if e.start > clock:
                return e.start
        return float("inf")

    def slowdown(self, clock: float) -> float:
        f = 1.0
        for e in self._active(clock):
            if e.kind == "slowdown":
                f *= e.factor
        return f

    def stall(self, clock: float) -> float:
        s = 0.0
        for e in self._active(clock):
            if e.kind == "hang":
                s += e.stall
        return s

    def check(self, q: int, clock: float) -> Optional[TransientQueryError]:
        """The typed failure query ``q`` hits at ``clock``, or None.

        Checked before the inner executor runs; flaky draws consume
        one ``(seed, replica, q, attempt)`` stream entry per *failed*
        attempt so a retry re-draws while a rerun replays."""
        active = self._active(clock)
        p_keep = 1.0
        stall = 0.0
        for e in active:
            if e.kind == "crash":
                until = e.end if self.plan.time_indexed else float("nan")
                return ReplicaUnavailableError(self.replica, until=until)
            if e.kind == "flaky":
                p_keep *= 1.0 - e.p
            elif e.kind == "hang":
                stall += e.stall
        if p_keep < 1.0:
            attempt = self._attempts.get(q, 0)
            u = np.random.default_rng(
                (self.plan.seed, _FLAKY_SALT, self.replica,
                 int(q), attempt)).random()
            if u < 1.0 - p_keep:
                self._attempts[q] = attempt + 1
                return TransientQueryError(
                    f"flaky fault failed query {q} (attempt {attempt})")
        if (self.timeout is not None and stall > self.timeout):
            return DispatchTimeoutError(self.timeout, self.replica)
        return None

    def clear(self, q: int) -> None:
        self._attempts.pop(q, None)

    def spans_fault(self, c0: float, c1: float) -> bool:
        """Any window overlapping the closed clock span ``[c0, c1]``?"""
        for e in self.events:
            if e.start > c1:
                return False
            if c0 < e.end:
                return True
        return False


class FaultingExecutor:
    """Fault-injecting wrapper around a query executor.

    Transparent when the plan is empty; raises/inflates per the plan
    otherwise.  Unknown attributes forward to the inner executor, so
    optional protocol extensions (``reference_throughput``,
    ``max_chunk``, ...) survive wrapping.
    """

    #: duck-typed marker: the runner arms its failure handling when the
    #: executor injects faults even without a RetrySpec (budget 0).
    injects_faults = True

    def __init__(self, inner, plan: FaultPlan, replica: int = 0,
                 timeout: Optional[float] = None):
        self.inner = inner
        self.injector = FaultInjector(plan, replica=replica,
                                      timeout=timeout)
        self._time_indexed = plan.time_indexed
        self._arrivals = None

    def __getattr__(self, name):
        return getattr(self.inner, name)

    # -- clock ----------------------------------------------------------------
    def _clock(self, q: int) -> float:
        if not self._time_indexed:
            return float(q)
        if self._arrivals is None:
            raise ValueError(
                "a time-indexed fault plan needs arrival times; "
                "open-loop workloads provide them (set_arrivals)")
        return float(self._arrivals[q])

    def set_arrivals(self, arrivals) -> None:
        self._arrivals = arrivals
        fwd = getattr(self.inner, "set_arrivals", None)
        if callable(fwd):
            fwd(arrivals)

    # -- protocol -------------------------------------------------------------
    @property
    def batch_mode(self):
        mode = getattr(self.inner, "batch_mode", None)
        if mode is None:
            return None
        if not callable(getattr(self.inner, "execute_many", None)):
            return None
        if not callable(getattr(self.inner, "steady_horizon", None)):
            return None
        return mode

    def begin_query(self, q: int):
        return self.inner.begin_query(q)

    def steady_horizon(self, q: int) -> int:
        has = getattr(self.inner, "steady_horizon", None)
        inner_h = int(has(q)) if callable(has) else _BIG
        inj = self.injector
        if not inj.events:
            return inner_h
        clock = self._clock(q)
        if inj.in_window(clock):
            return 1                   # in-window queries run scalar
        ns = inj.next_start(clock)
        if ns == float("inf"):
            return inner_h
        if self._time_indexed:
            # Number of queries arriving strictly before the window.
            idx = int(np.searchsorted(np.asarray(self._arrivals), ns,
                                      side="left"))
            fh = max(1, idx - q)
        else:
            fh = max(1, int(ns) - q)
        return min(inner_h, fh)

    def execute(self, q: int, step) -> QueryRecord:
        inj = self.injector
        clock = self._clock(q)
        err = inj.check(q, clock)
        if err is not None:
            raise err
        rec = self.inner.execute(q, step)
        f = inj.slowdown(clock)
        stall = inj.stall(clock)
        if f != 1.0 or stall != 0.0:
            sl = rec.service_latency * f + stall
            thr = rec.throughput
            if thr > 0.0:
                thr = 1.0 / (f / thr + stall)
            rec = QueryRecord(service_latency=sl, throughput=thr)
        inj.clear(q)
        return rec

    def execute_many(self, q0: int, steps) -> BatchRecord:
        n = len(steps)
        inj = self.injector
        if inj.events:
            c0, c1 = self._clock(q0), self._clock(q0 + n - 1)
            if inj.spans_fault(c0, c1):
                if n > 1:
                    raise RuntimeError(
                        "fault window inside a chunk; steady_horizon "
                        "should have cut here")
                rec = self.execute(q0, steps[0])
                return BatchRecord(
                    service_latencies=np.asarray([rec.service_latency]),
                    throughputs=np.asarray([rec.throughput]))
        return self.inner.execute_many(q0, steps)

    def configure_batching(self, former, lengths, padded) -> None:
        if former is not None:
            raise NotImplementedError(
                "fault injection does not compose with formed-dispatch "
                "batching (a multi-member dispatch has no per-query "
                "failure boundary); drop faults= or batching=")
        fwd = getattr(self.inner, "configure_batching", None)
        if callable(fwd):
            fwd(former, lengths, padded)

    # -- accounting -----------------------------------------------------------
    def fault_downtime(self, q_end: int, t_end: float) -> float:
        """Crash downtime accumulated by the end of the run, in the
        plan's clock units (queries or seconds)."""
        clock_end = float(t_end) if self._time_indexed else float(q_end)
        total = 0.0
        for e in self.injector.events:   # this replica's events only
            if e.kind == "crash":
                total += max(0.0, min(e.end, clock_end) - e.start)
        return total


__all__ = ["FaultInjector", "FaultingExecutor"]
