"""QoS tiers: per-request priority, deadline, and SLO value (docs/QOS.md).

Every query in the repo used to be identical — one SLO, one priority.
A :class:`QosTier` names a class of traffic (``interactive`` vs.
``best_effort``), carrying a *priority class* (who preempts whom at
batch formation), a *per-request deadline distribution* (seconds from
arrival), and an *SLO value* (what meeting that deadline is worth).
A :class:`TierAssigner` stamps every arrival with a tier draw — the
same seeded draw in the simulator and the live engine, so sim/live
runs see bit-identical tier sequences.

The stamped run is a :class:`TierPlan`: flat per-query arrays
(``tier_ids`` / ``priorities`` / ``deadlines`` / ``values``) that the
run loop indexes by global query id.  Drivers construct plans through
:func:`resolve_tiers`, mirroring ``resolve_lengths`` /
``resolve_admission``: a spec (names, ``QosTier`` objects, an
assigner, or a pre-built plan) in, a plan (or ``None`` — tiers
unarmed, bit-identical to the pre-QoS behaviour) out.

Deadline samplers are seeded and deterministic, registered by name
like the length samplers:

* ``fixed`` — every request the same deadline.
* ``uniform`` — deadlines uniform in ``[lo, hi]``.

Preset tiers live in a registry (``register_tier`` /
``get_tier``), so ``tiers="interactive,best_effort"`` works anywhere
a tier spec is accepted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type, Union

import numpy as np

# Distinct salts keep the tier-mixture draw and the per-tier deadline
# draws on independent streams of the same user seed.
_ASSIGN_SALT = 0x71A5
_DEADLINE_SALT = 0xD17E


# ------------------------------------------------------------------
# Deadline samplers
# ------------------------------------------------------------------

_DEADLINES: Dict[str, Type] = {}


def register_deadlines(name: str) -> Callable[[Type], Type]:
    """Class decorator registering a deadline sampler under ``name``."""
    def deco(cls: Type) -> Type:
        if name in _DEADLINES:
            raise ValueError(f"deadline sampler {name!r} already registered")
        _DEADLINES[name] = cls
        return cls
    return deco


def available_deadlines() -> List[str]:
    """Sorted names of every registered deadline sampler."""
    return sorted(_DEADLINES)


def make_deadlines(name: str, **kwargs):
    """Construct the deadline sampler registered under ``name``."""
    if name not in _DEADLINES:
        raise ValueError(f"unknown deadline sampler {name!r}; "
                         f"available: {available_deadlines()}")
    return _DEADLINES[name](**kwargs)


@register_deadlines("fixed")
class FixedDeadlines:
    """Every request the same relative deadline (``inf`` = no deadline)."""

    def __init__(self, deadline: float = math.inf):
        if not deadline > 0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
        self.deadline = float(deadline)

    def sample(self, num_queries: int, rng: np.random.Generator) -> np.ndarray:
        return np.full(num_queries, self.deadline, dtype=np.float64)


@register_deadlines("uniform")
class UniformDeadlines:
    """Per-request deadlines uniform in ``[lo, hi]`` seconds."""

    def __init__(self, lo: float, hi: float):
        if not 0 < lo <= hi or not math.isfinite(hi):
            raise ValueError(f"need 0 < lo <= hi (finite), got [{lo}, {hi}]")
        self.lo, self.hi = float(lo), float(hi)

    def sample(self, num_queries: int, rng: np.random.Generator) -> np.ndarray:
        return rng.uniform(self.lo, self.hi, size=num_queries)


# ------------------------------------------------------------------
# Tier model
# ------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QosTier:
    """One traffic class: priority, deadline distribution, SLO value.

    ``priority`` orders preemption (higher preempts lower at batch
    formation and routes first under ``downgrade``); ``value`` weights
    the tier in expected-value shedding and realized-value accounting;
    ``deadline`` is a sampler name (with ``deadline_kwargs``), a
    scalar number of seconds, or a sampler instance (anything with
    ``sample(n, rng)``).
    """

    name: str
    priority: int = 0
    value: float = 1.0
    deadline: Union[str, float, object] = math.inf
    deadline_kwargs: Optional[dict] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tier name must be non-empty")
        if not self.value > 0:
            raise ValueError(f"tier value must be > 0, got {self.value}")
        if (self.deadline_kwargs
                and not isinstance(self.deadline, str)):
            raise ValueError("deadline_kwargs only apply to a sampler name")

    def deadline_sampler(self):
        """The tier's deadline distribution as a sampler object."""
        if isinstance(self.deadline, str):
            return make_deadlines(self.deadline,
                                  **(self.deadline_kwargs or {}))
        if isinstance(self.deadline, (int, float)):
            return FixedDeadlines(float(self.deadline))
        return self.deadline


# Preset registry: names usable anywhere a tier spec is accepted.
_TIERS: Dict[str, QosTier] = {}


def register_tier(tier: QosTier, name: Optional[str] = None) -> QosTier:
    """Register a preset tier under ``name`` (default: ``tier.name``)."""
    key = name or tier.name
    if key in _TIERS:
        raise ValueError(f"tier {key!r} already registered")
    _TIERS[key] = tier
    return tier


def unregister_tier(name: str) -> None:
    """Remove a preset registration (tests / plugin reload)."""
    if name not in _TIERS:
        raise ValueError(f"tier {name!r} is not registered")
    del _TIERS[name]


def available_tiers() -> List[str]:
    """Sorted names of every registered preset tier."""
    return sorted(_TIERS)


def get_tier(name: str) -> QosTier:
    """Look up a preset tier by name."""
    if name not in _TIERS:
        raise ValueError(f"unknown tier {name!r}; "
                         f"available: {available_tiers()}")
    return _TIERS[name]


# The classic three-class split: latency-critical chat traffic, paid
# API traffic with a looser objective, and free-tier batch work that
# is worth serving but never worth displacing the first two.
register_tier(QosTier("interactive", priority=2, value=10.0, deadline=0.5))
register_tier(QosTier("standard", priority=1, value=2.0, deadline=2.0))
register_tier(QosTier("best_effort", priority=0, value=1.0, deadline=10.0))


# ------------------------------------------------------------------
# Per-run stamping
# ------------------------------------------------------------------

@dataclasses.dataclass
class TierPlan:
    """Per-query tier stamps for one run, indexed by global query id.

    ``deadlines`` are *relative* (seconds from the query's arrival);
    the run loop compares completion − arrival against them.  Arrays
    are plain numpy so a cluster can pre-size an empty plan per
    replica and stamp entries in assignment order.
    """

    tiers: Tuple[QosTier, ...]
    tier_ids: np.ndarray     # int64 [n] — index into ``tiers``
    priorities: np.ndarray   # int64 [n]
    deadlines: np.ndarray    # float64 [n] — relative, seconds
    values: np.ndarray       # float64 [n]

    def __post_init__(self):
        n = len(self.tier_ids)
        if not (len(self.priorities) == len(self.deadlines)
                == len(self.values) == n):
            raise ValueError("tier plan arrays must share one length")

    def __len__(self) -> int:
        return len(self.tier_ids)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    def take(self, num_queries: int) -> "TierPlan":
        """The plan truncated to the first ``num_queries`` stamps."""
        if num_queries > len(self):
            raise ValueError(f"tier plan covers {len(self)} queries, "
                             f"run needs {num_queries}")
        if num_queries == len(self):
            return self
        return TierPlan(self.tiers, self.tier_ids[:num_queries],
                        self.priorities[:num_queries],
                        self.deadlines[:num_queries],
                        self.values[:num_queries])

    @classmethod
    def empty(cls, tiers: Sequence[QosTier], capacity: int) -> "TierPlan":
        """A zeroed plan a cluster stamps in assignment order."""
        return cls(tuple(tiers),
                   np.zeros(capacity, dtype=np.int64),
                   np.zeros(capacity, dtype=np.int64),
                   np.full(capacity, math.inf, dtype=np.float64),
                   np.ones(capacity, dtype=np.float64))

    def stamp(self, local: int, source: "TierPlan", fleet_q: int) -> None:
        """Copy ``source``'s stamp for ``fleet_q`` into slot ``local``."""
        self.tier_ids[local] = source.tier_ids[fleet_q]
        self.priorities[local] = source.priorities[fleet_q]
        self.deadlines[local] = source.deadlines[fleet_q]
        self.values[local] = source.values[fleet_q]


@dataclasses.dataclass(frozen=True)
class QosRequest:
    """One arrival's QoS context, as handed to tier-aware routers.

    ``deadline`` here is *absolute* (arrival + relative deadline), so
    a router can compare it against projected completion times
    directly.
    """

    query: int
    tier: int
    priority: int
    deadline: float
    value: float


class TierAssigner:
    """Stamps arrivals with tiers: a seeded draw over a tier mixture.

    ``shares`` weight the mixture (normalized; default uniform).  The
    assignment and each tier's deadline draws run on independent
    seeded streams, so adding a tier perturbs neither the other
    tiers' deadlines nor the assignment of queries it does not claim
    beyond the mixture change itself.
    """

    def __init__(self, tiers: Sequence[QosTier],
                 shares: Optional[Sequence[float]] = None, seed: int = 0):
        tiers = tuple(tiers)
        if not tiers:
            raise ValueError("need at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"tier names must be unique, got {names}")
        if shares is None:
            shares = [1.0] * len(tiers)
        shares = np.asarray(shares, dtype=np.float64)
        if len(shares) != len(tiers) or np.any(shares < 0) or shares.sum() <= 0:
            raise ValueError("shares must be non-negative, sum > 0, and "
                             "match the tier count")
        self.tiers = tiers
        self.shares = shares / shares.sum()
        self.seed = int(seed)

    def assign(self, num_queries: int) -> TierPlan:
        rng = np.random.default_rng((self.seed, _ASSIGN_SALT))
        tier_ids = rng.choice(len(self.tiers), size=num_queries,
                              p=self.shares).astype(np.int64)
        priorities = np.array([t.priority for t in self.tiers],
                              dtype=np.int64)[tier_ids]
        values = np.array([t.value for t in self.tiers],
                          dtype=np.float64)[tier_ids]
        deadlines = np.empty(num_queries, dtype=np.float64)
        for i, tier in enumerate(self.tiers):
            mask = tier_ids == i
            drng = np.random.default_rng((self.seed, _DEADLINE_SALT, i))
            deadlines[mask] = tier.deadline_sampler().sample(
                int(mask.sum()), drng)
        return TierPlan(self.tiers, tier_ids, priorities, deadlines, values)


def resolve_tiers(tiers, tiers_kwargs: Optional[dict] = None,
                  num_queries: int = 0) -> Optional[TierPlan]:
    """One construction path for per-query tier stamps.

    ``tiers`` may be ``None`` (tiers unarmed — the run is bit-identical
    to a pre-QoS run), a pre-built :class:`TierPlan` (truncated to the
    run), an assigner (anything with ``assign``), a comma-joined
    string of preset names, or a sequence of tier specs — preset
    names, :class:`QosTier` objects, or dicts of ``QosTier`` fields.
    ``tiers_kwargs`` (``shares`` / ``seed``) apply when an assigner is
    built here.
    """
    if tiers is None:
        if tiers_kwargs:
            raise ValueError("tiers_kwargs given but no tiers selected")
        return None
    if isinstance(tiers, TierPlan):
        if tiers_kwargs:
            raise ValueError("tiers_kwargs only apply to a tier spec, "
                             "not an already-built TierPlan")
        return tiers.take(num_queries)
    if hasattr(tiers, "assign"):
        if tiers_kwargs:
            raise ValueError("tiers_kwargs only apply to a tier spec, "
                             "not an already-built assigner")
        return tiers.assign(num_queries)
    if isinstance(tiers, str):
        tiers = [part.strip() for part in tiers.split(",") if part.strip()]
    objs = []
    for spec in tiers:
        if isinstance(spec, str):
            objs.append(get_tier(spec))
        elif isinstance(spec, dict):
            objs.append(QosTier(**spec))
        else:
            objs.append(spec)
    return TierAssigner(objs, **(tiers_kwargs or {})).assign(num_queries)
