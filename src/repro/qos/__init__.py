"""QoS tiers: priorities, per-request deadlines, SLO value (docs/QOS.md)."""
from repro.qos.tiers import (
    FixedDeadlines,
    QosRequest,
    QosTier,
    TierAssigner,
    TierPlan,
    UniformDeadlines,
    available_deadlines,
    available_tiers,
    get_tier,
    make_deadlines,
    register_deadlines,
    register_tier,
    resolve_tiers,
    unregister_tier,
)

__all__ = [
    "FixedDeadlines",
    "QosRequest",
    "QosTier",
    "TierAssigner",
    "TierPlan",
    "UniformDeadlines",
    "available_deadlines",
    "available_tiers",
    "get_tier",
    "make_deadlines",
    "register_deadlines",
    "register_tier",
    "resolve_tiers",
    "unregister_tier",
]
