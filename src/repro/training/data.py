"""Synthetic data pipeline: deterministic, seekable token streams.

Generates Zipf-distributed token sequences with short-range structure
(a copy/induction pattern) so small models actually learn something the
loss curve can show.  The iterator is stateless-resumable (step index ->
batch), which is what checkpoint-resume requires.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        # Zipf weights over the vocab
        ranks = np.arange(1, vocab_size + 1)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.choice(self.vocab, size=(self.batch, self.seq + 1),
                          p=self._p)
        # induction pattern: second half repeats the first half shifted
        half = self.seq // 2
        toks[:, half:half * 2] = toks[:, :half]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticEmbeds:
    """For embedding-input (VLM/audio) models: frame/patch embeddings."""

    def __init__(self, d_model: int, vocab_size: int, seq_len: int,
                 global_batch: int, seed: int = 0):
        self.d = d_model
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step, 1))
        emb = rng.standard_normal(
            (self.batch, self.seq, self.d)).astype(np.float32) * 0.02
        labels = rng.integers(0, self.vocab, (self.batch, self.seq))
        return {"embeds": emb, "labels": labels.astype(np.int32)}
