"""Hand-rolled AdamW (optax is not assumed installed).

State is a pytree mirroring params: {m, v, count}.  Master math in fp32
regardless of param dtype; weight decay is decoupled.  The state layout
is sharding-friendly: m/v inherit the param PartitionSpec (ZeRO-style
extra sharding over the data axis is applied by launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(c: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    prog = jnp.clip((step - c.warmup_steps)
                    / jnp.maximum(c.total_steps - c.warmup_steps, 1), 0, 1)
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * jnp.where(step < c.warmup_steps, warm, cos)


def init_adamw(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(c: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(c, count)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * jnp.square(g)
        mhat = m / (1 - c.b1 ** count.astype(jnp.float32))
        vhat = v / (1 - c.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + c.eps)
        step = step + c.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
