from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw  # noqa: F401
from repro.training.train_loop import make_train_step, train  # noqa: F401
