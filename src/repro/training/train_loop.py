"""Training loop: loss + grad + AdamW, optionally pjit-sharded."""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, remat: bool = True
                    ) -> Callable:
    model = Model(cfg, remat=remat)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            opt, params, grads, opt_state)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, opt: AdamWConfig, data_iter, num_steps: int,
          rng=None, dtype=jnp.float32, log_every: int = 10,
          checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
          params=None, log_fn=print) -> Dict:
    model = Model(cfg, remat=True)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if params is None:
        params = model.init_params(rng, dtype)
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    history = []
    t_start = time.perf_counter()
    for step, batch in enumerate(data_iter):
        if step >= num_steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t_start
            history.append(m)
            log_fn(f"step {step:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                   f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}")
        if checkpoint_dir and checkpoint_every and step and \
                step % checkpoint_every == 0:
            from repro.training.checkpoint import save_checkpoint
            save_checkpoint(checkpoint_dir, {"params": params,
                                             "opt": opt_state}, step)
    return {"params": params, "opt_state": opt_state, "history": history}
