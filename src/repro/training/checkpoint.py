"""NumPy-based checkpointing (orbax is not assumed installed).

Saves a pytree as a flat .npz plus a JSON treedef manifest; atomic via
tmp-rename.  Works for params and optimizer state alike.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":   # e.g. ml_dtypes.bfloat16
            arr = arr.astype(np.float32)    # widen for .npz portability
        out[jax.tree_util.keystr(path)] = arr
    return out


def save_checkpoint(path: str, tree: Any, step: int) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(path, f".tmp-{step}.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, os.path.join(path, f"step-{step}.npz"))
    with open(os.path.join(path, "latest.json"), "w") as f:
        json.dump({"step": step}, f)


def latest_step(path: str) -> int:
    try:
        with open(os.path.join(path, "latest.json")) as f:
            return json.load(f)["step"]
    except FileNotFoundError:
        return -1


def load_checkpoint(path: str, tree_like: Any, step: int = -1) -> Any:
    """Restore into the structure of ``tree_like``."""
    if step < 0:
        step = latest_step(path)
        if step < 0:
            raise FileNotFoundError(f"no checkpoint under {path}")
    data = np.load(os.path.join(path, f"step-{step}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_, leaf in flat:
        key = jax.tree_util.keystr(path_)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        if arr.dtype != leaf.dtype:
            arr = jax.numpy.asarray(arr).astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
