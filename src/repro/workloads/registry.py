"""String-keyed workload registry (mirrors ``repro.schedulers.registry``).

Workload generators register under a name and are constructed through
``make_workload(name, **kwargs)``; the simulator, the live engine and
benchmark sweeps share one construction path.  Like the scheduler
registry, kwargs are filtered per class (``rate`` means nothing to
``closed``) while missing *required* arguments still raise (``trace``
without ``inter_arrivals``).
"""
from __future__ import annotations

from typing import Callable, List, Type

from repro.util.registry import Registry

# Importing the generators module runs its @register_workload
# decorators; lazy so registry.py itself stays import-cycle-free.
_REGISTRY = Registry("workload", builtins_module="repro.workloads.generators")


def register_workload(name: str, **defaults) -> Callable[[Type], Type]:
    """Class decorator registering a Workload under ``name``."""
    return _REGISTRY.register(name, **defaults)


def unregister_workload(name: str) -> None:
    """Remove a registration (tests / plugin reload)."""
    _REGISTRY.unregister(name)


def available_workloads() -> List[str]:
    """Sorted names of every registered workload."""
    return _REGISTRY.available()


def workload_class(name: str) -> Type:
    return _REGISTRY.cls(name)


def make_workload(name: str, **kwargs):
    """Construct the workload registered under ``name``."""
    return _REGISTRY.make(name, **kwargs)
