"""The one traffic-driven event loop shared by simulator and live engine.

``run_pipeline`` owns the per-query tick that ``simulate()`` and
``ServingEngine.serve()`` used to hand-roll separately: advance the
environment (interference events / slowdown schedules) via the
executor, poll the shared :class:`RebalanceRuntime` for the
configuration the query must run with, execute the query through the
driver's :class:`~repro.workloads.base.QueryExecutor`, and keep the
arrival-queue ledger that turns a :class:`~repro.workloads.base.Workload`
into per-query queueing delays and offered-vs-achieved load.

Queueing model: the pipeline admits one query per bottleneck beat.  A
pipelined query holds the admission head for ``1 / throughput`` (the
bottleneck stage time) and completes ``service_latency`` after it
starts; a serial (exploration-trial) query drains the pipeline and
holds the head for its full serial latency.  Closed-loop workloads
arrive exactly when the head frees up — zero queue delay, bit-identical
to the pre-workloads drivers.  Open-loop workloads arrive on their own
clock; when arrivals outpace admission, queries wait and
``latency = queue_delay + service_latency``.

Batch-granular fast path (docs/WORKLOADS.md "Batching & the fast
path"): executors that provide ``execute_many`` are driven in *chunks*
whenever the runtime is steady — no exploration phase in flight and no
detector transition pending.  A chunk never crosses a
rebalance-relevant boundary: an interference-event edge (the
executor's ``steady_horizon``), a detector trigger, a configuration
change, or the chunk cap.  Two flavors share the code:

* ``batch_mode = "vector"`` — the chunk is a pure computational
  speedup (the simulator): the scheduler is polled once per
  environment-steady segment (valid when the policy advertises
  ``steady_detect_stable``) and the whole arrival/queue/completion
  ledger is computed with vectorized numpy instead of the scalar tick.
* ``batch_mode = "batch"`` — the chunk is a *real* batch (the live
  engine): the scheduler is still polled per query, but queries that
  have already arrived are stacked and executed together, so a burst
  pays one set of stage dispatches instead of one per query.

Incremental driving (``repro.cluster``): the loop's state — admission
ledger, per-query arrays, rebalance-counter snapshots — lives in
:class:`PipelineRunner`, which also supports being fed one query at a
time via :meth:`PipelineRunner.step`.  A multi-replica
:class:`~repro.cluster.Cluster` owns one runner per replica and routes
each fleet arrival to one of them; ``run_pipeline`` itself is the
single-pipeline driver over the same runner.

Admission control (``repro.control``, docs/CONTROL.md): an
:class:`~repro.control.AdmissionPolicy` may shed arrivals the pipeline
cannot serve within its SLO.  A shed query never executes, never polls
the scheduler, and never advances the admission ledger; its arrival
time is recorded so the finished trace reports offered load, shed rate
and SLO attainment on *admitted* goodput.  Decisions are made at the
head of the loop with the actual ledger; inside a steady chunk a
predicted ledger (the runtime's estimated beat) decides where to cut —
exact for the simulator, whose steady chunks have constant beats.
Policies declaring ``admits_all`` (the ``none`` built-in) skip every
check, keeping closed-loop traces bit-identical to running without a
control plane.

Formed dispatch (``repro.workloads.batching``, docs/WORKLOADS.md
"Continuous batching & length buckets"): when a
:class:`~repro.workloads.batching.BatchFormer` is attached, queries are
served as *dispatches* — contiguous arrival-order runs sharing one
length bucket.  ``drain`` mode stacks the queued backlog at the
dispatch instant; ``continuous`` mode additionally folds arrivals in at
every pipeline-stage boundary via the executor's ``begin_dispatch``
builder.  Admission decisions happen only at dispatch *heads*: a query
that can join an in-flight batch is by construction being served
promptly, and keeping joiners shed-free is also what makes the chunked
and scalar paths take identical join/shed decisions (the vectorized
solo-stretch fast path proves a run of queries join-free from arrival
gaps alone, then admits them with the same predicted ledger the scalar
loop would).  With no former attached every batching branch is bypassed
— pre-former runs are bit-identical.
"""
from __future__ import annotations

import heapq
import inspect
from typing import TYPE_CHECKING, List, Optional, Tuple, Union

import numpy as np

from repro.control.base import AdmissionView
from repro.telemetry.streaming import StreamingCollector, StreamingTrace
from repro.util.errors import DispatchTimeoutError, TransientQueryError
from repro.workloads.base import QueryExecutor, Workload
from repro.workloads.lengths import resolve_lengths
from repro.workloads.registry import make_workload
from repro.workloads.trace import PipelineTrace

if TYPE_CHECKING:  # annotation-only: keeps workloads <-> schedulers acyclic
    from repro.control.base import AdmissionPolicy
    from repro.schedulers.runtime import RebalanceRuntime
    from repro.workloads.batching import BatchFormer

#: Fallback chunk cap when the executor does not prefer one.  Bounds the
#: temporary per-chunk arrays; segments longer than this simply split.
DEFAULT_MAX_CHUNK = 4096


def resolve_workload(workload: Union[str, Workload, None],
                     workload_kwargs: Optional[dict] = None) -> Workload:
    """Name (+ kwargs) or instance -> Workload instance."""
    if workload is None:
        workload = "closed"
    if isinstance(workload, str):
        return make_workload(workload, **(workload_kwargs or {}))
    if workload_kwargs:
        raise ValueError("workload_kwargs only apply to a workload name, "
                         "not an already-constructed instance")
    return workload


def resolve_arrivals(workload: Union[str, Workload, None],
                     workload_kwargs: Optional[dict],
                     num_queries: int) -> Tuple[str, Optional[np.ndarray]]:
    """Resolve a workload and materialize its arrival times.

    The shared prologue of every driver (``run_pipeline``, the
    cluster's fleet loop): returns ``(workload_name, arrival_times)``
    with ``arrival_times = None`` for a closed loop.
    """
    wl = resolve_workload(workload, workload_kwargs)
    wl_name = getattr(wl, "name", type(wl).__name__)
    gaps = wl.inter_arrivals(num_queries) if wl.open_loop else None
    if gaps is None:
        return wl_name, None
    if len(gaps) != num_queries:
        raise ValueError(f"workload {wl_name!r} produced {len(gaps)} "
                         f"inter-arrivals for {num_queries} queries")
    # Cumsum in place when the generator handed us a fresh array it
    # owns: at 10M+ queries the second O(n) float64 buffer is the
    # difference between flat and doubled RSS.  Workloads may legally
    # return views (TraceWorkload tiles a template), so fall back to an
    # out-of-place cumsum unless the array is provably ours to reuse.
    if (isinstance(gaps, np.ndarray) and gaps.dtype == np.float64
            and gaps.flags.owndata and gaps.flags.writeable):
        return wl_name, np.cumsum(gaps, out=gaps)
    return wl_name, np.cumsum(gaps)


class _CompletionLedger:
    """Completion times of admitted-but-unfinished queries.

    Replaces the old never-pruned ``bisect.insort`` list (O(n²) time and
    O(n) memory over a run) with a pruned min-heap: arrivals are
    monotone, so any completion ``<= arrival`` can never be counted by a
    later depth query and is dropped as the run advances — million-query
    runs stay O(n log n) with flat memory (the heap holds only the
    in-system queries, ~pipeline depth).
    """

    def __init__(self):
        self._heap: List[float] = []
        self._idx = np.arange(256)     # grown on demand, reused per chunk

    def depth_at(self, arrival: float) -> int:
        """In-system depth seen by an arrival (completions > arrival)."""
        heap = self._heap
        while heap and heap[0] <= arrival:
            heapq.heappop(heap)
        return len(heap)

    def push(self, completion: float) -> None:
        heapq.heappush(self._heap, completion)

    def depths_bulk(self, arrivals: np.ndarray,
                    completions: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`depth_at` + :meth:`push` for one chunk.

        ``arrivals`` and ``completions`` are the chunk's index-aligned
        ledger arrays; both are non-decreasing (chunks are
        environment-steady).  Depth ``i`` counts prior in-flight
        completions plus chunk members ``j < i`` still in flight.
        """
        if len(completions) > 1:
            dec = completions[:-1] - completions[1:]
            # Executors attribute per-query times with float arithmetic
            # whose rounding can wiggle mathematically-equal completions
            # by an ulp; only a *real* decrease breaks the contract.
            if bool(np.any(dec > 1e-9 * np.abs(completions[:-1]))):
                raise ValueError(
                    "chunk completion times must be non-decreasing")
            # Identity for truly monotone chunks (the simulator's — its
            # bit-exactness is untouched); irons out ulp wiggles so the
            # binary searches below stay well-defined.
            completions = np.maximum.accumulate(completions)
        prior = np.sort(self._heap) if self._heap else np.empty(0)
        depths = (len(prior) - np.searchsorted(prior, arrivals, side="right"))
        # Chunk members j < i with completion_j > arrival_i: completions
        # are monotone, so every counted entry precedes i (min-clip
        # handles the completion == arrival equality edge exactly).
        if len(arrivals) > len(self._idx):
            self._idx = np.arange(len(arrivals))
        idx = self._idx[:len(arrivals)]
        intra_done = np.searchsorted(completions, arrivals, side="right")
        depths = depths + idx - np.minimum(intra_done, idx)
        # Re-arm the heap: everything <= the chunk's last arrival can
        # never be counted again (arrivals are monotone run-wide).
        last = arrivals[-1]
        merged = np.concatenate([prior[prior > last],
                                 completions[completions > last]])
        self._heap = merged.tolist()
        heapq.heapify(self._heap)
        return depths


def _chunk_ledger(arrivals_chunk: Optional[np.ndarray],
                  occupancy: np.ndarray,
                  free_at: float) -> Tuple[np.ndarray, np.ndarray, float]:
    """Vectorized admission for one steady chunk.

    Returns ``(arrival, start, new_free_at)`` replicating the scalar
    recursion ``start_i = max(arrival_i, free_{i-1})``,
    ``free_i = start_i + occupancy_i``.  The closed loop (``arrivals_chunk
    is None``) uses a prepended cumsum so every floating-point addition
    happens in the same order as the scalar tick — bit-identical traces.
    The open loop uses the max-plus closed form
    (``np.maximum.accumulate``), exact up to float re-association.
    """
    if arrivals_chunk is None:
        # arrival_i = ready_i = free_{i-1}; start = arrival.
        c = np.cumsum(np.concatenate(([free_at], occupancy)))
        start = c[:-1]
        return start, start, float(c[-1])
    # start_i = O_i + max(free_at, max_{j<=i}(arrival_j - O_j)) with
    # O the exclusive prefix sum of occupancies.
    excl = np.concatenate(([0.0], np.cumsum(occupancy)[:-1]))
    base = np.maximum.accumulate(arrivals_chunk - excl)
    start = np.maximum(base, free_at) + excl
    return arrivals_chunk, start, float(start[-1] + occupancy[-1])


class PipelineRunner:
    """The event loop's state machine, driveable all-at-once or per query.

    One runner = one pipeline's serving window: it owns the admission
    ledger (``free_at`` / ``drain_at`` / in-system completions), the
    per-query result arrays, and the runtime-counter snapshots that make
    the finished :class:`PipelineTrace` report *this run's* rebalance
    accounting.

    Two driving modes share every line of tick code:

    * :meth:`run` — the single-pipeline driver behind
      :func:`run_pipeline`: consumes a whole arrival array, using the
      batch-granular fast path where the executor supports it.
    * :meth:`step` — feed exactly one query (the next one) with an
      explicit arrival time; used by :class:`repro.cluster.Cluster`,
      which interleaves routing decisions between queries and therefore
      cannot hand the loop the whole arrival stream upfront.

    ``capacity`` sizes the initial result arrays; serving past it grows
    them by doubling (a cluster pre-sizes each replica's runner at its
    *expected* share, not the whole fleet), and :meth:`finish` trims to
    the number actually served.

    ``admission`` is an optional :class:`~repro.control.AdmissionPolicy`
    instance; shed queries are recorded in :attr:`shed_arrivals` and
    the result arrays only ever hold *admitted* queries, so the dense
    array index and the global query index diverge once anything is
    shed (:attr:`num_served` vs. :attr:`num_offered`).
    """

    def __init__(self, executor: QueryExecutor,
                 runtime: RebalanceRuntime,
                 capacity: int,
                 chunking: bool = True,
                 max_chunk: Optional[int] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 trace_mode: str = "dense",
                 telemetry: Optional[StreamingCollector] = None,
                 former: Optional[BatchFormer] = None,
                 lengths: Optional[np.ndarray] = None,
                 padded: Optional[np.ndarray] = None,
                 retry=None,
                 tiers=None):
        if trace_mode not in ("dense", "streaming"):
            raise ValueError(f"unknown trace_mode {trace_mode!r}; "
                             f"expected 'dense' or 'streaming'")
        if trace_mode == "streaming" and telemetry is None:
            telemetry = StreamingCollector(
                slo=float(getattr(admission, "slo", float("inf"))
                          if admission is not None else float("inf")))
        self.executor = executor
        self.runtime = runtime
        self.capacity = max(1, int(capacity))
        self.trace_mode = trace_mode
        self.telemetry = telemetry

        self.admission = admission
        if admission is not None:
            admission.reset()
        # Hot-loop guards, resolved once: policies declaring admits_all
        # skip the shed checks entirely (bit-identity with no policy);
        # observe/bound hooks are optional protocol extensions.
        self._shed_check = (admission is not None
                            and not getattr(admission, "admits_all", False))
        self._observe = (getattr(admission, "observe", None)
                         if admission is not None else None)
        # Policies that understand batch occupancy (adaptive_batch) take
        # an ``occupancy`` keyword; older/custom observe hooks keep the
        # two-argument call.  Resolved once, outside the hot loop.
        self._observe_occ = False
        if self._observe is not None:
            try:
                params = inspect.signature(self._observe).parameters
                self._observe_occ = ("occupancy" in params or any(
                    p.kind is inspect.Parameter.VAR_KEYWORD
                    for p in params.values()))
            except (TypeError, ValueError):
                self._observe_occ = False
        self._chunk_bound = (getattr(admission, "max_chunk_bound", None)
                             if admission is not None else None)
        self.shed_arrivals: List[float] = []
        self.shed_indices: List[int] = []

        # QoS tiers (repro.qos; docs/QOS.md): a TierPlan stamps every
        # query this runner sees (indexed by the global query id) with
        # a priority class, a relative deadline, and an SLO value.
        # None = every tier branch below is dead code — no-tier runs
        # are bit-identical to pre-QoS runs.
        self._tiers = tiers
        if tiers is not None:
            self._tier_ids = tiers.tier_ids
            self._tier_pri = tiers.priorities
            self._tier_deadline = tiers.deadlines
            self._tier_value = tiers.values
            self.shed_tier_counts = np.zeros(len(tiers.tiers),
                                             dtype=np.int64)
        else:
            self._tier_ids = None
            self._tier_pri = None
            self._tier_deadline = None
            self._tier_value = None
            self.shed_tier_counts = None
        self.shed_value = 0.0          # offered value lost to shedding

        # Fault tolerance (repro.faults; docs/FAULTS.md): a RetrySpec
        # arms requeue-on-failure in :meth:`run`; a fault-injecting
        # executor arms the failure accounting even with no budget
        # (every transient failure is then terminal).  Neither present
        # = every guard below is a dead branch — pre-faults runs are
        # bit-identical.
        self._retry = retry
        self._fault_aware = (retry is not None
                             or getattr(executor, "injects_faults", False))
        self.num_failed = 0            # queries that exhausted the budget
        self.num_retried = 0           # retry attempts made
        self.num_hedged = 0            # hedged dispatches won here
        self.wasted_time = 0.0         # cancelled/hedged occupancy charged

        self._rebalances0 = runtime.num_rebalances
        self._trials0 = runtime.total_trials
        self._mitigations0 = len(runtime.mitigation_lengths)
        self._has_reference = hasattr(executor, "reference_throughput")

        # Sharded stage execution (docs/SHARDING.md): the mesh surface
        # exists only when the runtime carries a device assignment —
        # unsharded runs take none of the branches below.
        self._mesh_on = getattr(runtime, "mesh", None) is not None
        self._resizes0 = getattr(runtime, "num_mesh_resizes", 0)

        mode = getattr(executor, "batch_mode", None) if chunking else None
        if mode is not None and not callable(getattr(executor,
                                                     "execute_many", None)):
            mode = None
        if mode not in (None, "vector", "batch"):
            raise ValueError(f"unknown executor batch_mode {mode!r}; "
                             f"expected 'vector', 'batch' or None")
        if mode is not None and not callable(getattr(executor,
                                                     "steady_horizon", None)):
            raise ValueError("a batching executor must provide "
                             "steady_horizon(q); chunks must not cross an "
                             "interference edge")
        self._mode = mode
        cap = (max_chunk if max_chunk is not None
               else getattr(executor, "max_chunk", DEFAULT_MAX_CHUNK))
        self._chunk_cap = max(1, int(cap))

        # Batch formation (docs/WORKLOADS.md "Continuous batching &
        # length buckets").  The former is policy; the executor's
        # begin_dispatch builder is mechanism.  None = every batching
        # branch below is dead code — pre-former runs are untouched.
        self._former = former
        self._lengths = None if lengths is None else np.asarray(lengths)
        self._padded = None if padded is None else np.asarray(padded)
        if former is not None:
            if not callable(getattr(executor, "begin_dispatch", None)):
                raise ValueError(
                    "batching needs an executor providing "
                    "begin_dispatch(q, step); got "
                    f"{type(executor).__name__}")
            if not callable(getattr(executor, "steady_horizon", None)):
                raise ValueError(
                    "batching needs an executor providing "
                    "steady_horizon(q); dispatches must not cross an "
                    "interference edge")
        # Optional (wall, throughput, last_join_offset) oracle enabling
        # the vectorized solo-stretch fast path; without it every query
        # goes through the dispatch loop (correct, just scalar).
        self._profile = (getattr(executor, "dispatch_profile", None)
                         if former is not None else None)
        # "vector" chunks poll the scheduler once per environment-steady
        # segment, which is only equivalent to per-query polling when the
        # policy's steady detect is stable (pure under unchanged
        # conditions).
        self._poll_once = mode == "vector" and runtime.steady_poll_stable()

        # Streaming mode: the result arrays are a bounded recycling
        # scratch, not the run's storage — cap them near the chunk cap
        # and flush to the collector whenever the next chunk might not
        # fit (the +2 leaves room for a chunk's polled-but-unchunkable
        # leftover query).  Dense mode with a collector attached flushes
        # on a fixed cadence without recycling, so sinks still see
        # periodic snapshots at zero behavioural change.
        self._streaming = trace_mode == "streaming"
        self._keep_configs = not self._streaming
        self._last_config: Optional[List[int]] = None
        if self._streaming:
            self.capacity = min(self.capacity,
                                max(8192, 2 * (self._chunk_cap + 2)))
            self.capacity = max(self.capacity, self._chunk_cap + 2)
        self._flush_at = self.capacity - (self._chunk_cap + 2)
        self.num_flushed = 0           # recycled-away rows (streaming)
        self._stream_pos = 0           # first unobserved row (dense+sink)

        n = self.capacity
        self.latencies = np.zeros(n)
        self.service_lat = np.zeros(n)
        self.queue_delay = np.zeros(n)
        self.throughputs = np.zeros(n)
        self.serial_mask = np.zeros(n, dtype=bool)
        self.arrival_t = np.zeros(n)
        self.completion_t = np.zeros(n)
        self.queue_depth = np.zeros(n, dtype=int)
        self.rc_thr = np.zeros(n) if self._has_reference else None
        self.batch_sizes = np.zeros(n)   # dispatch size each row rode in
        self.padded_tok = np.zeros(n)    # padded tokens charged to the row
        self.actual_tok = np.zeros(n)    # useful tokens (actual length)
        if tiers is not None:
            self.tier_row = np.zeros(n)      # tier id the row was stamped
            self.deadline_row = np.zeros(n)  # relative deadline, seconds
            self.value_row = np.zeros(n)     # SLO value of the row
        else:
            self.tier_row = None
            self.deadline_row = None
            self.value_row = None
        if tiers is not None and self.telemetry is not None:
            self.telemetry.configure_tiers(tiers.names)
        self.coll_frac = np.zeros(n) if self._mesh_on else None
        self.configs_trace: List[List[int]] = []
        self.mesh_trace: List[List[int]] = []

        self.free_at = 0.0             # when the admission head frees up
        self.drain_at = 0.0            # when every admitted query completed
        self._pending = _CompletionLedger()  # in-system completions
        self.num_served = 0            # queries executed (admitted) so far
        self.num_offered = 0           # queries offered (incl. shed) so far

    #: Result arrays grown together when the run outlives ``capacity``.
    _ARRAYS = ("latencies", "service_lat", "queue_delay", "throughputs",
               "serial_mask", "arrival_t", "completion_t", "queue_depth",
               "rc_thr", "batch_sizes", "padded_tok", "actual_tok",
               "tier_row", "deadline_row", "value_row", "coll_frac")

    def _ensure_capacity(self, n: int) -> None:
        """Grow the result arrays (doubling) to hold ``n`` queries."""
        if n <= self.capacity:
            return
        new = max(n, 2 * self.capacity)
        for name in self._ARRAYS:
            arr = getattr(self, name)
            if arr is None:
                continue
            grown = np.zeros(new, dtype=arr.dtype)
            grown[:len(arr)] = arr
            setattr(self, name, grown)
        self.capacity = new

    # -- ticks (shared by both driving modes) -------------------------------
    def _scalar_tick(self, gq: int, step, arrival: Optional[float],
                     not_before: Optional[float] = None) -> float:
        """One query through the per-query (compatibility) path.

        ``gq`` is the global query index (what the executor sees);
        results land at the dense index :attr:`num_served`, which the
        tick advances.  ``arrival = None`` means closed-loop: the query
        arrives exactly when the pipeline can take it.  ``not_before``
        floors the start time (retry backoff holds, all-unhealthy
        waits); the extra wait lands in the query's queue delay.
        Returns the completion time.
        """
        s = self.num_served
        rec = self.executor.execute(gq, step)
        self.throughputs[s] = rec.throughput
        self.serial_mask[s] = step.serial
        if self._mesh_on:
            self.coll_frac[s] = rec.collective_frac
        if self._keep_configs:
            self.configs_trace.append(list(step.config))
            if self._mesh_on:
                self.mesh_trace.append(list(step.mesh))
        else:
            self._last_config = list(step.config)
        # A serial trial runs on the drained pipeline, so it cannot
        # start until every in-flight pipelined query has completed.
        ready = (max(self.free_at, self.drain_at) if step.serial
                 else self.free_at)
        if arrival is None:
            arrival = ready
        self.queue_depth[s] = self._pending.depth_at(arrival)
        start = max(arrival, ready)
        if not_before is not None and not_before > start:
            start = not_before
        occupancy = (rec.service_latency if step.serial
                     else (1.0 / rec.throughput if rec.throughput > 0
                           else 0.0))
        self.free_at = start + occupancy
        completion = start + rec.service_latency
        self.drain_at = max(self.drain_at, completion)
        self._pending.push(completion)
        self.arrival_t[s] = arrival
        self.completion_t[s] = completion
        self.queue_delay[s] = start - arrival
        self.service_lat[s] = rec.service_latency
        self.latencies[s] = self.queue_delay[s] + rec.service_latency
        self.batch_sizes[s] = 1.0
        if self._padded is not None:
            self.padded_tok[s] = float(self._padded[gq])
            self.actual_tok[s] = float(self._lengths[gq])
        else:
            self.padded_tok[s] = 0.0
            self.actual_tok[s] = 0.0
        if self._tier_ids is not None:
            self.tier_row[s] = self._tier_ids[gq]
            self.deadline_row[s] = self._tier_deadline[gq]
            self.value_row[s] = self._tier_value[gq]
        self.num_served = s + 1
        return completion

    def _retry_tick(self, gq: int, step, arrival: Optional[float],
                    err: TransientQueryError) -> Optional[float]:
        """Failure handling for the single-pipeline driver.

        Query ``gq``'s first execution attempt raised ``err``.  Charge
        the failure (a timed-out hang occupied the head for the full
        timeout before cancellation), then retry under the budget with
        exponential-backoff start holds.  Returns the completion time
        on eventual success, None when the budget is exhausted (the
        query is counted failed and writes no row).
        """
        retry = self._retry
        attempt = 0
        hold = None
        while True:
            ready = (max(self.free_at, self.drain_at) if step.serial
                     else self.free_at)
            fail_t = ready if arrival is None else max(float(arrival), ready)
            if hold is not None and hold > fail_t:
                fail_t = hold
            if isinstance(err, DispatchTimeoutError):
                self.free_at = fail_t + err.timeout
                self.wasted_time += err.timeout
                fail_t = self.free_at
            if retry is None or attempt >= retry.max_retries:
                self.num_failed += 1
                return None
            hold = fail_t + retry.delay(gq, attempt)
            attempt += 1
            self.num_retried += 1
            try:
                return self._scalar_tick(gq, step, arrival,
                                         not_before=hold)
            except TransientQueryError as e:
                err = e

    def charge_occupancy(self, arrival: Optional[float],
                         occupancy: float) -> float:
        """Occupy the admission head without recording a row — a hedge
        loser's cancelled dispatch (docs/FAULTS.md).  The occupancy is
        charged as wasted work; returns the new ``free_at``."""
        start = (self.free_at if arrival is None
                 else max(self.free_at, float(arrival)))
        self.free_at = start + float(occupancy)
        self.wasted_time += float(occupancy)
        return self.free_at

    def stamp_tier(self, local: int, plan, fleet_q: int) -> None:
        """Stamp local slot ``local`` with fleet query ``fleet_q``'s
        tier draw from the fleet ``plan`` (the cluster's assign path).
        Keyed overwrite like ``on_assign``: a failed dispatch serves no
        row, so a retry re-assigns the same slot.  The runner's local
        plan arrays grow on demand — routing skew may push one replica
        past its pre-sized fleet share."""
        if self._tier_ids is None:
            raise ValueError("stamp_tier needs the runner built with a "
                             "tier plan (tiers=TierPlan.empty(...))")
        if local >= len(self._tier_ids):
            new = max(local + 1, 2 * len(self._tier_ids))
            for name, fill in (("_tier_ids", 0), ("_tier_pri", 0),
                               ("_tier_deadline", np.inf),
                               ("_tier_value", 1.0)):
                arr = getattr(self, name)
                grown = np.full(new, fill, dtype=arr.dtype)
                grown[:len(arr)] = arr
                setattr(self, name, grown)
        self._tier_ids[local] = plan.tier_ids[fleet_q]
        self._tier_pri[local] = plan.priorities[fleet_q]
        self._tier_deadline[local] = plan.deadlines[fleet_q]
        self._tier_value[local] = plan.values[fleet_q]

    def _chunk_tick(self, gq0: int, steps,
                    arr_chunk: Optional[np.ndarray]) -> None:
        """``len(steps)`` steady queries through ``execute_many``.

        ``gq0`` is the chunk's first global query index; ``arr_chunk``
        holds the chunk members' arrival times (``None`` = closed
        loop).  Results land at dense indices ``num_served ..
        num_served + len(steps) - 1``.
        """
        n = len(steps)
        s0 = self.num_served
        sl = slice(s0, s0 + n)
        rec = self.executor.execute_many(gq0, steps)
        if len(rec.throughputs) != n:
            raise ValueError(f"execute_many returned {len(rec.throughputs)} "
                             f"records for a chunk of {n}")
        self.throughputs[sl] = rec.throughputs
        self.serial_mask[sl] = False   # chunks are steady by construction
        if self._mesh_on:
            self.coll_frac[sl] = (rec.collective_fracs
                                  if rec.collective_fracs is not None
                                  else 0.0)
        if not self._keep_configs:
            self._last_config = list(steps[-1].config)
        elif steps[0] is steps[-1]:
            # poll-once chunks replicate one step: share one row object
            # instead of materializing n copies (entries are read-only
            # by convention; the scalar path appends fresh lists).
            self.configs_trace.extend([list(steps[0].config)] * n)
        else:
            self.configs_trace.extend(list(s.config) for s in steps)
        if self._mesh_on and self._keep_configs:
            if steps[0] is steps[-1]:
                self.mesh_trace.extend([list(steps[0].mesh)] * n)
            else:
                self.mesh_trace.extend(list(s.mesh) for s in steps)
        occ = np.where(rec.throughputs > 0, 1.0 / rec.throughputs, 0.0)
        arrival, start, self.free_at = _chunk_ledger(arr_chunk, occ,
                                                     self.free_at)
        completion = start + rec.service_latencies
        self.queue_depth[sl] = self._pending.depths_bulk(arrival, completion)
        self.drain_at = max(self.drain_at, float(completion[-1]))
        self.arrival_t[sl] = arrival
        self.completion_t[sl] = completion
        self.queue_delay[sl] = start - arrival
        self.service_lat[sl] = rec.service_latencies
        self.latencies[sl] = self.queue_delay[sl] + rec.service_latencies
        # "batch" chunks are one physical execution (n-wide occupancy);
        # "vector" chunks are a computational speedup over solo queries.
        self.batch_sizes[sl] = float(n) if self._mode == "batch" else 1.0
        if self._padded is not None:
            self.padded_tok[sl] = self._padded[gq0:gq0 + n]
            self.actual_tok[sl] = self._lengths[gq0:gq0 + n]
        else:
            self.padded_tok[sl] = 0.0
            self.actual_tok[sl] = 0.0
        if self._tier_ids is not None:
            self.tier_row[sl] = self._tier_ids[gq0:gq0 + n]
            self.deadline_row[sl] = self._tier_deadline[gq0:gq0 + n]
            self.value_row[sl] = self._tier_value[gq0:gq0 + n]
        self.num_served = s0 + n

    # -- formed dispatch (repro.workloads.batching; docs/WORKLOADS.md) -------
    def _dispatch_tick(self, q: int, step, arrivals: Optional[np.ndarray],
                       end: int) -> int:
        """Form and execute one dispatch headed by global query ``q``
        (already admitted and polled).  Returns the next global index.

        Formation stacks already-arrived same-bucket queries at the
        dispatch instant; continuous mode additionally joins arrivals
        at every stage boundary the executor's builder reports.
        Joiners are *not* admission-checked (head-only shedding — see
        the module docstring) but are polled, so an exploration trial
        or a config change still cuts the batch: the polled query
        becomes the leftover, executed scalar right after the dispatch
        drains.  A serial head (``explore_in_batch``) skips polling its
        riders entirely — one trial per poll is an explorer invariant —
        and rides the dispatch pipelined instead of draining first.
        """
        executor, runtime, former = self.executor, self.runtime, self._former
        arrival = float(arrivals[q]) if arrivals is not None else None
        t0 = self.free_at if arrival is None else max(arrival, self.free_at)
        serial_head = step.serial
        pw = self._padded
        cap = min(former.max_batch, self._chunk_cap_now())
        # Candidate window: the head's steady segment (a joiner must
        # share the head's environment — its poll is only reusable and
        # the builder's catch-up arithmetic only valid there).  Skip the
        # possibly-costly horizon scan when no candidate can exist.
        if arrivals is None or q + 1 >= end or cap == 1:
            wlimit = q + 1
        elif not former.continuous and arrivals[q + 1] > t0:
            wlimit = q + 1     # drain mode with no backlog: solo by definition
        else:
            wlimit = q + min(end - q,
                             max(1, int(executor.steady_horizon(q))))
        s0 = self.num_served
        self._ensure_capacity(s0 + min(cap, end - q) + 1)
        builder = executor.begin_dispatch(q, step)
        builder.add(q)
        members = [q]
        j = q + 1
        leftover = None
        stop = False
        pri = self._tier_pri
        batch_pri = int(pri[q]) if pri is not None else 0

        def try_fill(ready: float, joining: bool) -> None:
            nonlocal j, leftover, stop, batch_pri
            while (j < wlimit and len(members) < cap
                   and arrivals[j] <= ready):
                # Dispatches are single-bucket — formation and joins
                # alike.  Padding a narrow joiner up to a wide batch is
                # shape-legal but prices the padded row's full compute
                # in every remaining stage (the cost model is linear in
                # padded tokens), which balloons the dispatch for the
                # whole backlog behind it; the bucket cut keeps joins
                # strictly win-win.
                if pw is not None and pw[j] != pw[q]:
                    stop = True
                    return
                # Formation-slot preemption (docs/QOS.md): once the
                # dispatch carries a query of some priority class, a
                # lower-priority candidate may not extend it — batched
                # dispatch is group-synchronous, so every additional
                # member pushes the shared drain (and with it the
                # high-priority member's completion) further out.  The
                # refused candidate is not polled and simply heads the
                # next dispatch.  Higher-priority candidates still
                # join: joining completes at this dispatch's drain,
                # strictly earlier than waiting to head their own.
                if pri is not None and pri[j] < batch_pri:
                    stop = True
                    return
                if not serial_head:
                    src = executor.begin_query(j)
                    if self.rc_thr is not None:
                        self.rc_thr[s0 + len(members)] = \
                            executor.reference_throughput(j)
                    stp = (runtime.poll(src) if src is not None
                           else runtime.steady_step())
                    if (stp.serial or stp.config != step.config
                            or stp.mesh != step.mesh):
                        leftover = (j, stp)
                        stop = True
                        j += 1
                        return
                elif self.rc_thr is not None:
                    # Riders of a trial are not polled; the reference
                    # oracle is env-pure, and the env is steady here.
                    self.rc_thr[s0 + len(members)] = \
                        executor.reference_throughput(j)
                (builder.join if joining else builder.add)(j)
                members.append(j)
                if pri is not None and pri[j] > batch_pri:
                    batch_pri = int(pri[j])
                j += 1

        if arrivals is not None:
            try_fill(t0, joining=False)
            if former.continuous:
                while not stop and j < wlimit and len(members) < cap:
                    b = builder.next_boundary()
                    if b is None:
                        break
                    try_fill(t0 + b, joining=True)
        rec = builder.finish()

        n = len(members)
        sl = slice(s0, s0 + n)
        mem = np.asarray(members)
        arr_m = arrivals[mem] if arrivals is not None else np.full(n, t0)
        starts = t0 + rec.start_offsets
        completion = t0 + float(rec.drain)
        thr = float(rec.throughput)
        # Batched dispatch is group-synchronous: the dispatch holds the
        # admission head for its full drain (thr = 1/drain), and the
        # next dispatch launches only after this one retires; a riding
        # trial deliberately skips the old drain-the-pipeline wait.
        self.free_at = t0 + (1.0 / thr if thr > 0 else 0.0)
        self.drain_at = max(self.drain_at, completion)
        completions = np.full(n, completion)
        self.queue_depth[sl] = self._pending.depths_bulk(arr_m, completions)
        self.throughputs[sl] = n * thr
        self.serial_mask[sl] = False
        self.serial_mask[s0] = serial_head
        if self._mesh_on:
            self.coll_frac[sl] = rec.collective_frac
        if self._keep_configs:
            self.configs_trace.extend([list(step.config)] * n)
            if self._mesh_on:
                self.mesh_trace.extend([list(step.mesh)] * n)
        else:
            self._last_config = list(step.config)
        self.arrival_t[sl] = arr_m
        self.completion_t[sl] = completions
        qd = starts - arr_m
        sv = float(rec.drain) - rec.start_offsets
        self.queue_delay[sl] = qd
        self.service_lat[sl] = sv
        self.latencies[sl] = qd + sv
        self.batch_sizes[sl] = float(n)
        if pw is not None:
            # Every row of the dispatch occupies the head's bucket
            # width (formation members and joiners alike share the
            # head's bucket — dispatches are single-bucket).
            width = float(pw[q])
            pmem = np.full(n, width)
            amem = self._lengths[mem].astype(float)
            # Batch-dimension padding (the live engine rounds rows up to
            # a warm power-of-two) is dispatch-level waste: charge it to
            # the head row.  A relative threshold keeps the analytic
            # builders' token sums (sequential adds vs. np.sum pairwise,
            # ulp apart) from perturbing per-row values.
            extra = float(rec.padded_tokens) - width * n
            if extra > 1e-9 * max(float(rec.padded_tokens), 1.0):
                pmem[0] += extra
            self.padded_tok[sl] = pmem
            self.actual_tok[sl] = amem
        else:
            self.padded_tok[sl] = float(rec.padded_tokens) / n
            self.actual_tok[sl] = float(rec.actual_tokens) / n
        if self._tier_ids is not None:
            self.tier_row[sl] = self._tier_ids[mem]
            self.deadline_row[sl] = self._tier_deadline[mem]
            self.value_row[sl] = self._tier_value[mem]
        self.num_served = s0 + n

        if leftover is not None:
            jq, jstep = leftover
            self._scalar_tick(jq, jstep,
                              float(arrivals[jq]) if arrivals is not None
                              else None)
        if self._observe is not None:
            self._observe_span(s0)
        return j

    def _solo_window(self, q: int, step,
                     arrivals: Optional[np.ndarray], end: int) -> int:
        """Length of the provably join-free run of dispatch heads at ``q``.

        A query is *solo* when its successor arrives after its last
        join opportunity (dispatch start plus the final stage-boundary
        offset; the dispatch instant itself in drain mode).  Solo
        queries are bit-identical to singleton dispatches, so the
        poll-once vector fast path serves the whole run through
        ``execute_many`` instead of one builder per query.  Returns 0
        when the head itself may receive joiners.
        """
        executor = self.executor
        cap = self._chunk_cap_now()
        if arrivals is None:
            # Closed loop: the next query arrives only once the head
            # frees up, never strictly inside a dispatch — all solo.
            return min(end - q, cap,
                       max(1, int(executor.steady_horizon(q))))
        horizon = max(1, int(executor.steady_horizon(q)))
        limit = min(end - q, horizon)
        open_end = True        # successor beyond window cannot join
        if cap < limit:
            limit, open_end = cap, False
        pw = self._padded
        if pw is not None and limit > 1:
            w = pw[q:q + limit]
            diff = np.nonzero(w != w[0])[0]
            if len(diff):
                limit, open_end = int(diff[0]), True
        _, thr, join_off = self._profile(q, step.config)
        if not self._former.continuous:
            join_off = 0.0     # drain mode: joins only at the dispatch instant
        occ = 1.0 / thr if thr > 0 else 0.0
        _, starts, _ = _chunk_ledger(arrivals[q:q + limit],
                                     np.full(limit, occ), self.free_at)
        if limit > 1:
            solo = arrivals[q + 1:q + limit] > starts[:-1] + join_off
            bad = np.nonzero(~solo)[0]
            m = int(bad[0]) if len(bad) else limit
        else:
            m = 1
        if m == limit and not open_end:
            # Window cut by the chunk cap: the successor exists in the
            # same environment and may join the last member — leave
            # that member to the dispatch loop.
            nxt = q + limit
            if (arrivals[nxt] <= starts[-1] + join_off
                    and (pw is None or pw[nxt] == pw[q])):
                m = limit - 1
        if self._shed_check and m > 1:
            # Heads shed exactly as the scalar loop would: the shadow
            # ledger advances by the dispatch occupancy, which for solo
            # stretches is the actual occupancy — prediction is exact.
            m = self._admit_horizon(q, m, arrivals, occ_est=occ)
        return m

    # -- admission control (repro.control; docs/CONTROL.md) ------------------
    def _view(self, gq: int, arrival: Optional[float], wait: float,
              est_service: float, est_latency: float) -> AdmissionView:
        """The admission view for query ``gq`` — one construction path
        for the actual-ledger decision and the chunked pre-pass, so
        tiered decisions are identical on both."""
        if self._tier_ids is None:
            return AdmissionView(query=gq, arrival=arrival, wait=wait,
                                 est_service=est_service,
                                 est_latency=est_latency)
        return AdmissionView(query=gq, arrival=arrival, wait=wait,
                             est_service=est_service,
                             est_latency=est_latency,
                             tier=int(self._tier_ids[gq]),
                             priority=int(self._tier_pri[gq]),
                             deadline=float(self._tier_deadline[gq]),
                             value=float(self._tier_value[gq]))

    def _admit(self, gq: int, arrival: Optional[float]) -> bool:
        """Admit-or-shed decision for global query ``gq``, made with
        the *actual* ledger.  A shed is recorded and never executes."""
        wait = (0.0 if arrival is None
                else max(self.free_at - arrival, 0.0))
        view = self._view(gq, arrival, wait,
                          self.runtime.estimated_bottleneck(),
                          self.runtime.estimated_service_latency())
        if self.admission.admit(view):
            return True
        t = self.free_at if arrival is None else float(arrival)
        if self._tier_ids is not None:
            tid = int(self._tier_ids[gq])
            val = float(self._tier_value[gq])
            self.shed_tier_counts[tid] += 1
            self.shed_value += val
            if self.telemetry is not None:
                self.telemetry.observe_shed(t, tier=tid, value=val)
        elif self.telemetry is not None:
            self.telemetry.observe_shed(t)
        if not self._streaming:
            # Streaming keeps sheds as counters only — these lists are
            # O(shed) and a saturating policy sheds millions.
            self.shed_indices.append(gq)
            self.shed_arrivals.append(t)
        return False

    def _admit_horizon(self, gq0: int, limit: int,
                       arrivals: Optional[np.ndarray],
                       occ_est: Optional[float] = None) -> int:
        """Largest ``n <= limit`` such that queries ``gq0+1 ..
        gq0+n-1`` are all predicted to be admitted (``gq0`` itself was
        already admitted with the actual ledger).

        The prediction advances a shadow of the admission head by the
        runtime's estimated beat per member — exact for the
        simulator's steady chunks, where the estimate *is* the actual
        occupancy.  The first predicted shed cuts the chunk; that
        query is then re-decided (and recorded) by the outer loop
        against the post-chunk actual ledger.

        ``occ_est`` overrides the shadow-ledger advance (the former's
        solo-stretch path passes the dispatch-adjusted occupancy, which
        folds in the batch overhead and padded-length cost model); the
        policy's *view* always carries the raw runtime estimates either
        way, matching what a scalar head decision would see.
        """
        est = self.runtime.estimated_bottleneck()
        est_lat = self.runtime.estimated_service_latency()
        if occ_est is None:
            occ_est = est if np.isfinite(est) and est > 0 else 0.0
        a0 = arrivals[gq0] if arrivals is not None else None
        free_pred = (max(float(a0), self.free_at) + occ_est
                     if a0 is not None else self.free_at + occ_est)
        for j in range(gq0 + 1, gq0 + limit):
            if arrivals is None:
                arrival, wait = None, 0.0
            else:
                arrival = float(arrivals[j])
                wait = max(free_pred - arrival, 0.0)
            view = self._view(j, arrival, wait, est, est_lat)
            if not self.admission.admit(view):
                return j - gq0
            free_pred = (free_pred + occ_est if arrival is None
                         else max(arrival, free_pred) + occ_est)
        return limit

    def _chunk_cap_now(self) -> int:
        """Chunk cap, shrunk by the policy's live bound when present
        (``adaptive_batch``'s SLO-aware ``max_batch`` control)."""
        if self._chunk_bound is None:
            return self._chunk_cap
        return max(1, min(self._chunk_cap, int(self._chunk_bound())))

    def _observe_span(self, s0: int) -> None:
        """Feed the policy's observe hook every query executed since
        dense index ``s0`` (its measured queue delay + service time,
        plus the dispatch occupancy it rode in when the hook takes it)."""
        if self._observe_occ:
            for s in range(s0, self.num_served):
                self._observe(float(self.queue_delay[s]),
                              float(self.service_lat[s]),
                              occupancy=float(self.batch_sizes[s]))
        else:
            for s in range(s0, self.num_served):
                self._observe(float(self.queue_delay[s]),
                              float(self.service_lat[s]))

    # -- telemetry flushing (repro.telemetry; docs/TELEMETRY.md) -------------
    @property
    def total_served(self) -> int:
        """Admitted queries over the whole run, including rows already
        recycled into the collector (= :attr:`num_served` in dense
        mode, where nothing is recycled)."""
        return self.num_flushed + self.num_served

    def _should_flush(self) -> bool:
        if self._streaming:
            return self.num_served >= self._flush_at
        return self.num_served - self._stream_pos >= 1024

    def flush_telemetry(self) -> None:
        """Feed every row since the last flush to the collector; in
        streaming mode the arrays are then recycled (dense indices
        reset — the ledger's *times* carry all cross-flush state)."""
        tel = self.telemetry
        if tel is None:
            return
        s0, s1 = self._stream_pos, self.num_served
        if s1 > s0:
            tier_cols = {}
            if self._tier_ids is not None:
                tier_cols = dict(tier_ids=self.tier_row[s0:s1],
                                 deadlines=self.deadline_row[s0:s1],
                                 values=self.value_row[s0:s1])
            tel.observe_chunk(
                latencies=self.latencies[s0:s1],
                service_latencies=self.service_lat[s0:s1],
                queue_delays=self.queue_delay[s0:s1],
                throughputs=self.throughputs[s0:s1],
                serial_mask=self.serial_mask[s0:s1],
                arrival_times=self.arrival_t[s0:s1],
                completion_times=self.completion_t[s0:s1],
                queue_depths=self.queue_depth[s0:s1],
                batch_sizes=self.batch_sizes[s0:s1],
                padded_tokens=self.padded_tok[s0:s1],
                actual_tokens=self.actual_tok[s0:s1],
                **tier_cols)
        if self._fault_aware:
            tel.note_faults(self.num_failed, self.num_retried,
                            self.num_hedged, self.wasted_time,
                            self.fault_downtime())
        if self._streaming:
            self.num_flushed += s1
            self.num_served = 0
            self._stream_pos = 0
        else:
            self._stream_pos = s1

    # -- incremental driving (one query at a time) --------------------------
    def step(self, arrival: Optional[float] = None,
             not_before: Optional[float] = None) -> float:
        """Serve the next query, arriving at ``arrival`` (None = the
        instant this pipeline can take it — closed loop).

        The per-query semantics are identical to :meth:`run`'s scalar
        path: advance the environment, poll the scheduler runtime,
        execute, account the arrival ledger.  Returns the query's
        completion time, which callers (the cluster's routers) use for
        outstanding-work accounting.

        ``not_before`` floors the start time (the cluster's retry
        backoff and all-unhealthy waits).  With a fault-injecting
        executor this may raise a
        :class:`~repro.util.errors.TransientQueryError`; the ledger is
        untouched in that case (no row, ``num_offered`` unchanged) and
        the *caller* owns the retry/failure decision — the cluster
        catches here so retries can re-route across replicas.
        """
        if self.telemetry is not None and self._should_flush():
            self.flush_telemetry()
        gq = self.num_offered          # global index (= dense when no sheds)
        s = self.num_served
        self._ensure_capacity(s + 1)
        source = self.executor.begin_query(gq)
        if self.rc_thr is not None:
            self.rc_thr[s] = self.executor.reference_throughput(gq)
        step = (self.runtime.poll(source) if source is not None
                else self.runtime.steady_step())
        completion = self._scalar_tick(gq, step, arrival, not_before)
        self.num_offered = gq + 1
        return completion

    def step_many(self, arrivals) -> List[float]:
        """Serve several already-routed queries in one call, grouping
        steady same-config runs through ``execute_many``.

        The cluster's rebatch path (docs/CLUSTER.md): a replica that
        accumulated a routed backlog flushes it here instead of
        query-by-query :meth:`step`, so a burst pays one set of stage
        dispatches.  Arrival times must be non-decreasing and already
        in the past at flush time (a real batch can only stack queries
        that have arrived).  Like :meth:`step`, no admission check is
        made here — the cluster sheds at its own routing layer.
        Returns the per-query completion times in arrival order.

        Fault semantics (docs/FAULTS.md): a
        :class:`~repro.util.errors.TransientQueryError` raised mid-flush
        carries the completed prefix on ``err.partial_completions`` —
        the completions of every query that executed before the failing
        dispatch (those rows are already in the ledger); the failing
        query and the tail behind it remain unserved, and the caller
        decides their fate per ``RetrySpec.batch_policy``.
        """
        arr = np.asarray(arrivals, dtype=float)
        n = len(arr)
        if n == 0:
            return []
        if self._mode is None or n == 1:
            out = []
            try:
                for a in arr:
                    out.append(self.step(float(a)))
            except TransientQueryError as err:
                err.partial_completions = out
                raise
            return out
        executor, runtime = self.executor, self.runtime
        out: List[float] = []
        try:
            self._step_many_body(arr, n, out, executor, runtime)
        except TransientQueryError as err:
            err.partial_completions = out
            raise
        return out

    def _step_many_body(self, arr, n: int, out: List[float],
                        executor, runtime) -> None:
        i = 0
        while i < n:
            if self.telemetry is not None and self._should_flush():
                self.flush_telemetry()
            gq = self.num_offered
            self._ensure_capacity(self.num_served + (n - i) + 1)
            source = executor.begin_query(gq)
            s0 = self.num_served
            if self.rc_thr is not None:
                self.rc_thr[s0] = executor.reference_throughput(gq)
            step = (runtime.poll(source) if source is not None
                    else runtime.steady_step())
            if step.serial:
                out.append(self._scalar_tick(gq, step, float(arr[i])))
                self.num_offered = gq + 1
                i += 1
                continue
            limit = min(n - i, self._chunk_cap_now(),
                        max(1, int(executor.steady_horizon(gq))))
            steps = [step]
            leftover = None
            j = 1
            while j < limit:
                src_j = executor.begin_query(gq + j)
                if self.rc_thr is not None:
                    self.rc_thr[s0 + j] = executor.reference_throughput(gq + j)
                step_j = (runtime.poll(src_j) if src_j is not None
                          else runtime.steady_step())
                if (step_j.serial or step_j.config != step.config
                        or step_j.mesh != step.mesh):
                    leftover = step_j
                    break
                steps.append(step_j)
                j += 1
            k = len(steps)
            self._chunk_tick(gq, steps, arr[i:i + k])
            out.extend(self.completion_t[s0:s0 + k].tolist())
            self.num_offered = gq + k
            i += k
            if leftover is not None:
                # Polled but not chunkable (trial or config change):
                # execute scalar without re-advancing the runtime.
                out.append(self._scalar_tick(gq + k, leftover, float(arr[i])))
                self.num_offered += 1
                i += 1

    # -- full-run driving (the run_pipeline path) ---------------------------
    def run(self, num_queries: int,
            arrivals: Optional[np.ndarray]) -> None:
        """Serve ``num_queries`` offered queries with the given arrival
        times (``None`` = closed loop), using the batch-granular fast
        path where the executor supports it.  ``arrivals`` is indexed
        by the *global* query index; shed queries (admission control)
        consume an index without executing."""
        if not self._streaming:
            # Streaming keeps the arrays at their fixed recycling
            # capacity; growing them to the run length is exactly the
            # O(n) footprint the mode exists to avoid.
            self._ensure_capacity(self.num_served + num_queries)
        executor, runtime = self.executor, self.runtime
        mode = self._mode
        rc_thr = self.rc_thr
        shed_check, observe = self._shed_check, self._observe
        telemetry = self.telemetry
        fault_aware = self._fault_aware

        q = self.num_offered
        end = q + num_queries
        while q < end:
            if telemetry is not None and self._should_flush():
                self.flush_telemetry()
            arrival = arrivals[q] if arrivals is not None else None
            # -- admit or shed, with the actual ledger --------------------
            if shed_check and not self._admit(q, arrival):
                q += 1
                continue
            # -- advance the environment; poll the scheduler runtime ------
            source = executor.begin_query(q)
            s0 = self.num_served
            if rc_thr is not None:
                rc_thr[s0] = executor.reference_throughput(q)
            step = runtime.poll(source) if source is not None \
                else runtime.steady_step()

            # -- formed dispatch (batch former attached) -------------------
            if self._former is not None:
                former = self._former
                if step.serial and not former.explore_in_batch:
                    # Trials drain the pipeline exactly as before unless
                    # the former opts them into riding a dispatch.
                    self._scalar_tick(q, step, arrival)
                    if observe is not None:
                        self._observe_span(s0)
                    q += 1
                    continue
                if (self._poll_once and not step.serial
                        and self._profile is not None):
                    m = self._solo_window(q, step, arrivals, end)
                    if m >= 1:
                        # Join-free run: singleton dispatches, served
                        # vectorized — bit-identical to the scalar loop.
                        if rc_thr is not None:
                            rc_thr[s0:s0 + m] = rc_thr[s0]
                        self._chunk_tick(q, [step] * m,
                                         arrivals[q:q + m]
                                         if arrivals is not None else None)
                        if observe is not None:
                            self._observe_span(s0)
                        q += m
                        continue
                q = self._dispatch_tick(q, step, arrivals, end)
                continue

            if mode is None or step.serial:
                if fault_aware:
                    try:
                        self._scalar_tick(q, step, arrival)
                    except TransientQueryError as err:
                        self._retry_tick(q, step, arrival, err)
                else:
                    self._scalar_tick(q, step, arrival)
                if observe is not None:
                    self._observe_span(s0)
                q += 1
                continue

            if mode == "batch":
                # A real batch only forms from queries already queued at
                # dispatch time; don't pay the steady-horizon scan (up to
                # max_chunk schedule evaluations) when there is no
                # backlog.
                dispatch_t = (max(self.free_at, arrivals[q])
                              if arrivals is not None else self.free_at)
                if (arrivals is None or q + 1 >= end
                        or arrivals[q + 1] > dispatch_t):
                    if fault_aware:
                        try:
                            self._chunk_tick(q, [step],
                                             arrivals[q:q + 1]
                                             if arrivals is not None
                                             else None)
                        except TransientQueryError as err:
                            self._retry_tick(q, step, arrival, err)
                    else:
                        self._chunk_tick(q, [step],
                                         arrivals[q:q + 1]
                                         if arrivals is not None else None)
                    if observe is not None:
                        self._observe_span(s0)
                    q += 1
                    continue

            limit = min(end - q,
                        self._chunk_cap_now(),
                        max(1, int(executor.steady_horizon(q))))
            if shed_check and limit > 1:
                # Cut the chunk at the first *predicted* shed; the cut
                # query is re-decided by the loop head afterwards.
                limit = self._admit_horizon(q, limit, arrivals)

            if self._poll_once:
                # One poll covers the whole environment-steady segment:
                # the policy's detect is pure under unchanged (config,
                # stage times), so queries q+1 .. q+limit-1 would poll
                # identically.
                n = limit
                if rc_thr is not None:
                    rc_thr[s0:s0 + n] = rc_thr[s0]
                if fault_aware:
                    # A faultable chunk is single-query by construction
                    # (the injector's steady_horizon forces 1 inside
                    # fault windows), so the retry path stays scalar.
                    try:
                        self._chunk_tick(q, [step] * n,
                                         arrivals[q:q + n]
                                         if arrivals is not None else None)
                    except TransientQueryError as err:
                        self._retry_tick(q, step, arrival, err)
                else:
                    self._chunk_tick(q, [step] * n,
                                     arrivals[q:q + n]
                                     if arrivals is not None else None)
                if observe is not None:
                    self._observe_span(s0)
                q += n
                continue

            # Per-query polling ("batch" mode, or "vector" with a
            # stateful detector): accumulate steady same-config queries,
            # stopping at the steady horizon, the chunk cap, a detector
            # trigger, a config change, or — for real batches — the
            # arrival backlog (a query that has not arrived by dispatch
            # time cannot join).
            steps = [step]
            leftover = None          # (q, step) polled but not chunk-able
            dispatch_t = (max(self.free_at, arrivals[q])
                          if arrivals is not None else self.free_at)
            j = q + 1
            while j < q + limit:
                if mode == "batch" and (arrivals is None
                                        or arrivals[j] > dispatch_t):
                    break
                src_j = executor.begin_query(j)
                if rc_thr is not None:
                    rc_thr[s0 + len(steps)] = executor.reference_throughput(j)
                step_j = runtime.poll(src_j) if src_j is not None \
                    else runtime.steady_step()
                if (step_j.serial or step_j.config != step.config
                        or step_j.mesh != step.mesh):
                    leftover = (j, step_j)
                    break
                steps.append(step_j)
                j += 1
            if fault_aware:
                try:
                    self._chunk_tick(q, steps,
                                     arrivals[q:q + len(steps)]
                                     if arrivals is not None else None)
                except TransientQueryError as err:
                    self._retry_tick(q, step, arrival, err)
            else:
                self._chunk_tick(q, steps,
                                 arrivals[q:q + len(steps)]
                                 if arrivals is not None else None)
            q += len(steps)
            if leftover is not None:
                # Already polled (the trial/commit is charged to this
                # query); execute it without re-advancing the runtime.
                jq, jstep = leftover
                self._scalar_tick(
                    jq, jstep,
                    arrivals[jq] if arrivals is not None else None)
                q += 1
            if observe is not None:
                self._observe_span(s0)
        self.num_offered = q

    # -- result --------------------------------------------------------------
    def finish(self, scheduler_name: str = "",
               workload_name: str = "closed",
               peak_throughput: float = float("nan")
               ) -> Union[PipelineTrace, StreamingTrace]:
        """Freeze the run into a :class:`PipelineTrace` (arrays trimmed
        to the number of queries actually served; shed queries are
        reported through the trace's shed/goodput surface).  In
        streaming mode the remaining rows are flushed and the result is
        the collector's :class:`StreamingTrace` instead."""
        admission_name = ("none" if self.admission is None
                          else getattr(self.admission, "name",
                                       type(self.admission).__name__))
        slo = float(getattr(self.admission, "slo", float("inf"))
                    if self.admission is not None else float("inf"))
        if self.telemetry is not None:
            self.flush_telemetry()
        downtime = self.fault_downtime()
        if self._streaming:
            return self.telemetry.finish(
                scheduler=scheduler_name, workload=workload_name,
                peak_throughput=peak_throughput, admission=admission_name,
                num_rebalances=self.runtime.num_rebalances
                - self._rebalances0,
                total_trials=self.runtime.total_trials - self._trials0,
                mitigation_lengths=list(
                    self.runtime.mitigation_lengths[self._mitigations0:]),
                final_config=self._last_config,
                num_failed=self.num_failed, num_retried=self.num_retried,
                num_hedged=self.num_hedged, wasted_time=self.wasted_time,
                downtime=downtime)
        if self.telemetry is not None:
            self.telemetry.emit()     # final sink snapshot (dense+sink)
        n = self.num_served
        return PipelineTrace(
            scheduler=scheduler_name,
            latencies=self.latencies[:n],
            throughputs=self.throughputs[:n],
            serial_mask=self.serial_mask[:n],
            configs_trace=self.configs_trace,
            num_rebalances=self.runtime.num_rebalances - self._rebalances0,
            total_trials=self.runtime.total_trials - self._trials0,
            mitigation_lengths=list(
                self.runtime.mitigation_lengths[self._mitigations0:]),
            workload=workload_name,
            service_latencies=self.service_lat[:n],
            queue_delays=self.queue_delay[:n],
            arrival_times=self.arrival_t[:n],
            completion_times=self.completion_t[:n],
            queue_depths=self.queue_depth[:n],
            peak_throughput=peak_throughput,
            rc_throughputs=(self.rc_thr[:n] if self.rc_thr is not None
                            else None),
            admission=admission_name,
            slo_latency=slo,
            shed_arrivals=np.asarray(self.shed_arrivals, dtype=float),
            batch_sizes=self.batch_sizes[:n],
            padded_tokens=self.padded_tok[:n],
            actual_tokens=self.actual_tok[:n],
            num_failed=self.num_failed,
            num_retried=self.num_retried,
            num_hedged=self.num_hedged,
            wasted_time=self.wasted_time,
            downtime=downtime,
            tier_names=(self._tiers.names if self._tiers is not None
                        else None),
            tier_ids=(self.tier_row[:n].astype(np.int64)
                      if self.tier_row is not None else None),
            tier_deadlines=(self.deadline_row[:n]
                            if self.deadline_row is not None else None),
            tier_values=(self.value_row[:n]
                         if self.value_row is not None else None),
            shed_tier_counts=self.shed_tier_counts,
            shed_value=self.shed_value,
            mesh_devices=(int(sum(self.runtime.mesh)) if self._mesh_on
                          else 0),
            mesh_trace=(self.mesh_trace if self._mesh_on else None),
            collective_fracs=(self.coll_frac[:n] if self._mesh_on
                              else None),
            num_mesh_resizes=(self.runtime.num_mesh_resizes
                              - self._resizes0 if self._mesh_on else 0),
        )

    def fault_downtime(self) -> float:
        """Crash downtime the executor's fault plan accumulated over
        this run (0.0 without an injecting executor)."""
        hook = getattr(self.executor, "fault_downtime", None)
        if callable(hook):
            return float(hook(self.num_offered, self.drain_at))
        return 0.0


def _run_pipeline_impl(executor: QueryExecutor,
                 runtime: RebalanceRuntime,
                 num_queries: int,
                 workload: Union[str, Workload, None] = "closed",
                 workload_kwargs: Optional[dict] = None,
                 scheduler_name: str = "",
                 peak_throughput: float = float("nan"),
                 chunking: bool = True,
                 max_chunk: Optional[int] = None,
                 admission: Union[str, object, None] = None,
                 admission_kwargs: Optional[dict] = None,
                 trace_mode: str = "dense",
                 metrics_sink=None,
                 sink_interval: Optional[int] = None,
                 former: Optional[BatchFormer] = None,
                 lengths=None,
                 lengths_kwargs: Optional[dict] = None,
                 faults=None,
                 retries=None,
                 tiers=None,
                 tiers_kwargs: Optional[dict] = None
                 ) -> Union[PipelineTrace, StreamingTrace]:
    """Serve ``num_queries`` arrivals of ``workload`` through one
    scheduler runtime; returns the unified :class:`PipelineTrace`.

    ``runtime`` counters are snapshotted so the trace reports *this
    run's* rebalance accounting even when a runtime is reused across
    serving windows (the live engine's pattern).

    ``chunking=False`` forces the scalar per-query tick even when the
    executor supports ``execute_many`` (benchmark baseline / debugging);
    ``max_chunk`` overrides the executor's preferred chunk cap.

    ``admission`` selects an :class:`~repro.control.AdmissionPolicy`
    (registry name + ``admission_kwargs``, or an instance;
    docs/CONTROL.md).  ``None`` / ``"none"`` admits everything —
    closed-loop results are bit-identical to a run without a control
    plane either way.

    ``trace_mode="streaming"`` (docs/TELEMETRY.md) accumulates metrics
    online at flat memory and returns a
    :class:`~repro.telemetry.StreamingTrace` — same ``summary()`` keys,
    percentiles within sketch tolerance.  ``metrics_sink`` receives
    periodic :class:`~repro.telemetry.MetricsRegistry` snapshots every
    ~``sink_interval`` queries in *either* mode (dense results stay
    bit-identical with a sink attached).

    ``former`` attaches a resolved
    :class:`~repro.workloads.batching.BatchFormer` (drivers build one
    via ``resolve_batching``); the executor must provide the
    ``begin_dispatch`` builder and a ``configure_batching`` hook.
    ``lengths`` / ``lengths_kwargs`` attach a per-query sequence-length
    distribution (sampler name, instance, or explicit array —
    ``repro.workloads.lengths``); without a former lengths are
    accounting-only (token counters in the trace).

    ``tiers`` / ``tiers_kwargs`` stamp every arrival with a QoS tier
    (``repro.qos``, docs/QOS.md): tier-aware admission policies see
    priority/deadline/value on their views, batch formation refuses
    low-priority extensions of high-priority dispatches, and the trace
    grows per-tier latency/attainment/value accounting.  ``None`` =
    tiers unarmed, bit-identical to pre-QoS runs.
    """
    # Deferred import: repro.control registers its builtins on first
    # use; the run loop itself only needs the resolver.
    from repro.control.registry import resolve_admission
    policy = resolve_admission(admission, admission_kwargs)

    tier_plan = None
    if tiers is not None or tiers_kwargs:
        from repro.qos import resolve_tiers
        tier_plan = resolve_tiers(tiers, tiers_kwargs, num_queries)

    # Fault tolerance (repro.faults; docs/FAULTS.md): wrap the executor
    # in a fault injector and arm the runner's retry budget.  Both
    # default off — the wrapped/armed branches are then never taken and
    # pre-faults traces stay bit-identical.
    retry_spec = None
    if faults is not None or retries is not None:
        from repro.faults import (FaultingExecutor, resolve_faults,
                                  resolve_retries)
        retry_spec = resolve_retries(retries)
        plan = resolve_faults(faults)
        if plan is not None:
            executor = FaultingExecutor(
                executor, plan,
                timeout=(retry_spec.timeout if retry_spec is not None
                         else None))

    telemetry = None
    if trace_mode == "streaming" or metrics_sink is not None:
        from repro.telemetry.streaming import DEFAULT_SINK_INTERVAL
        telemetry = StreamingCollector(
            slo=float(getattr(policy, "slo", float("inf"))
                      if policy is not None else float("inf")),
            sink=metrics_sink,
            sink_interval=(sink_interval if sink_interval is not None
                           else DEFAULT_SINK_INTERVAL))

    wl = resolve_workload(workload, workload_kwargs)
    wl_name, arrivals = resolve_arrivals(wl, None, num_queries)
    lengths_arr = resolve_lengths(lengths, lengths_kwargs, num_queries,
                                  workload=wl)
    padded = None
    if former is not None:
        padded = former.padded_lengths(lengths_arr)
        configure = getattr(executor, "configure_batching", None)
        if not callable(configure):
            raise ValueError(
                "batching requires an executor providing "
                "configure_batching(former, lengths, padded); got "
                f"{type(executor).__name__}")
        configure(former, lengths_arr, padded)
    elif lengths_arr is not None:
        # Accounting-only lengths: padded == actual, no cost model.
        padded = lengths_arr
        configure = getattr(executor, "configure_batching", None)
        if callable(configure):
            configure(None, lengths_arr, padded)
    # Executors whose interference timeline is wall-clock anchored
    # (time-indexed events, docs/CLUSTER.md) need each query's arrival
    # time to advance the environment.
    announce = getattr(executor, "set_arrivals", None)
    if callable(announce):
        announce(arrivals)

    runner = PipelineRunner(executor, runtime, num_queries,
                            chunking=chunking, max_chunk=max_chunk,
                            admission=policy, trace_mode=trace_mode,
                            telemetry=telemetry, former=former,
                            lengths=lengths_arr, padded=padded,
                            retry=retry_spec, tiers=tier_plan)
    runner.run(num_queries, arrivals)
    return runner.finish(scheduler_name=scheduler_name,
                         workload_name=wl_name,
                         peak_throughput=peak_throughput)


def run_pipeline(executor: QueryExecutor,
                 runtime: RebalanceRuntime,
                 num_queries: int,
                 workload: Union[str, Workload, None] = "closed",
                 workload_kwargs: Optional[dict] = None,
                 scheduler_name: str = "",
                 peak_throughput: float = float("nan"),
                 chunking: bool = True,
                 max_chunk: Optional[int] = None,
                 admission: Union[str, object, None] = None,
                 admission_kwargs: Optional[dict] = None,
                 trace_mode: str = "dense",
                 metrics_sink=None,
                 sink_interval: Optional[int] = None,
                 former: Optional[BatchFormer] = None,
                 lengths=None,
                 lengths_kwargs: Optional[dict] = None,
                 faults=None,
                 retries=None,
                 tiers=None,
                 tiers_kwargs: Optional[dict] = None
                 ) -> Union[PipelineTrace, StreamingTrace]:
    """Serve ``num_queries`` arrivals through one scheduler runtime.

    Thin wrapper over the unified :class:`repro.api.RunSpec` path (one
    declaration, one dispatcher — docs/API.md); the kwargs here map
    1:1 onto spec fields and new options land on the spec instead of
    this signature.  See :func:`_run_pipeline_impl` for the full
    kwarg-level documentation (unchanged semantics, bit-identical
    traces).
    """
    from repro import api
    spec = api.RunSpec(
        executor=executor, runtime=runtime, num_queries=num_queries,
        peak_throughput=peak_throughput,
        scheduler=api.SchedulerSpec(name=(scheduler_name or "")),
        workload=api.WorkloadSpec(name=workload, kwargs=workload_kwargs),
        admission=api.AdmissionSpec(name=admission,
                                    kwargs=admission_kwargs),
        batching=api.BatchingSpec(chunking=chunking, max_chunk=max_chunk,
                                  former=former, lengths=lengths,
                                  lengths_kwargs=lengths_kwargs),
        faults=api.FaultsSpec(plan=faults),
        retries=api.RetriesSpec(policy=retries),
        tiers=api.TiersSpec(spec=tiers, kwargs=tiers_kwargs),
        telemetry=api.TelemetrySpec(trace_mode=trace_mode,
                                    metrics_sink=metrics_sink,
                                    sink_interval=sink_interval))
    return api.run(spec)
