"""The one traffic-driven event loop shared by simulator and live engine.

``run_pipeline`` owns the per-query tick that ``simulate()`` and
``ServingEngine.serve()`` used to hand-roll separately: advance the
environment (interference events / slowdown schedules) via the
executor, poll the shared :class:`RebalanceRuntime` for the
configuration the query must run with, execute the query through the
driver's :class:`~repro.workloads.base.QueryExecutor`, and keep the
arrival-queue ledger that turns a :class:`~repro.workloads.base.Workload`
into per-query queueing delays and offered-vs-achieved load.

Queueing model: the pipeline admits one query per bottleneck beat.  A
pipelined query holds the admission head for ``1 / throughput`` (the
bottleneck stage time) and completes ``service_latency`` after it
starts; a serial (exploration-trial) query drains the pipeline and
holds the head for its full serial latency.  Closed-loop workloads
arrive exactly when the head frees up — zero queue delay, bit-identical
to the pre-workloads drivers.  Open-loop workloads arrive on their own
clock; when arrivals outpace admission, queries wait and
``latency = queue_delay + service_latency``.

Batch-granular fast path (docs/WORKLOADS.md "Batching & the fast
path"): executors that provide ``execute_many`` are driven in *chunks*
whenever the runtime is steady — no exploration phase in flight and no
detector transition pending.  A chunk never crosses a
rebalance-relevant boundary: an interference-event edge (the
executor's ``steady_horizon``), a detector trigger, a configuration
change, or the chunk cap.  Two flavors share the code:

* ``batch_mode = "vector"`` — the chunk is a pure computational
  speedup (the simulator): the scheduler is polled once per
  environment-steady segment (valid when the policy advertises
  ``steady_detect_stable``) and the whole arrival/queue/completion
  ledger is computed with vectorized numpy instead of the scalar tick.
* ``batch_mode = "batch"`` — the chunk is a *real* batch (the live
  engine): the scheduler is still polled per query, but queries that
  have already arrived are stacked and executed together, so a burst
  pays one set of stage dispatches instead of one per query.

Incremental driving (``repro.cluster``): the loop's state — admission
ledger, per-query arrays, rebalance-counter snapshots — lives in
:class:`PipelineRunner`, which also supports being fed one query at a
time via :meth:`PipelineRunner.step`.  A multi-replica
:class:`~repro.cluster.Cluster` owns one runner per replica and routes
each fleet arrival to one of them; ``run_pipeline`` itself is the
single-pipeline driver over the same runner.
"""
from __future__ import annotations

import heapq
from typing import TYPE_CHECKING, List, Optional, Tuple, Union

import numpy as np

from repro.workloads.base import QueryExecutor, Workload
from repro.workloads.registry import make_workload
from repro.workloads.trace import PipelineTrace

if TYPE_CHECKING:  # annotation-only: keeps workloads <-> schedulers acyclic
    from repro.schedulers.runtime import RebalanceRuntime

#: Fallback chunk cap when the executor does not prefer one.  Bounds the
#: temporary per-chunk arrays; segments longer than this simply split.
DEFAULT_MAX_CHUNK = 4096


def resolve_workload(workload: Union[str, Workload, None],
                     workload_kwargs: Optional[dict] = None) -> Workload:
    """Name (+ kwargs) or instance -> Workload instance."""
    if workload is None:
        workload = "closed"
    if isinstance(workload, str):
        return make_workload(workload, **(workload_kwargs or {}))
    if workload_kwargs:
        raise ValueError("workload_kwargs only apply to a workload name, "
                         "not an already-constructed instance")
    return workload


def resolve_arrivals(workload: Union[str, Workload, None],
                     workload_kwargs: Optional[dict],
                     num_queries: int) -> Tuple[str, Optional[np.ndarray]]:
    """Resolve a workload and materialize its arrival times.

    The shared prologue of every driver (``run_pipeline``, the
    cluster's fleet loop): returns ``(workload_name, arrival_times)``
    with ``arrival_times = None`` for a closed loop.
    """
    wl = resolve_workload(workload, workload_kwargs)
    wl_name = getattr(wl, "name", type(wl).__name__)
    gaps = wl.inter_arrivals(num_queries) if wl.open_loop else None
    if gaps is not None and len(gaps) != num_queries:
        raise ValueError(f"workload {wl_name!r} produced {len(gaps)} "
                         f"inter-arrivals for {num_queries} queries")
    return wl_name, (np.cumsum(gaps) if gaps is not None else None)


class _CompletionLedger:
    """Completion times of admitted-but-unfinished queries.

    Replaces the old never-pruned ``bisect.insort`` list (O(n²) time and
    O(n) memory over a run) with a pruned min-heap: arrivals are
    monotone, so any completion ``<= arrival`` can never be counted by a
    later depth query and is dropped as the run advances — million-query
    runs stay O(n log n) with flat memory (the heap holds only the
    in-system queries, ~pipeline depth).
    """

    def __init__(self):
        self._heap: List[float] = []
        self._idx = np.arange(256)     # grown on demand, reused per chunk

    def depth_at(self, arrival: float) -> int:
        """In-system depth seen by an arrival (completions > arrival)."""
        heap = self._heap
        while heap and heap[0] <= arrival:
            heapq.heappop(heap)
        return len(heap)

    def push(self, completion: float) -> None:
        heapq.heappush(self._heap, completion)

    def depths_bulk(self, arrivals: np.ndarray,
                    completions: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`depth_at` + :meth:`push` for one chunk.

        ``arrivals`` and ``completions`` are the chunk's index-aligned
        ledger arrays; both are non-decreasing (chunks are
        environment-steady).  Depth ``i`` counts prior in-flight
        completions plus chunk members ``j < i`` still in flight.
        """
        if len(completions) > 1:
            dec = completions[:-1] - completions[1:]
            # Executors attribute per-query times with float arithmetic
            # whose rounding can wiggle mathematically-equal completions
            # by an ulp; only a *real* decrease breaks the contract.
            if bool(np.any(dec > 1e-9 * np.abs(completions[:-1]))):
                raise ValueError(
                    "chunk completion times must be non-decreasing")
            # Identity for truly monotone chunks (the simulator's — its
            # bit-exactness is untouched); irons out ulp wiggles so the
            # binary searches below stay well-defined.
            completions = np.maximum.accumulate(completions)
        prior = np.sort(self._heap) if self._heap else np.empty(0)
        depths = (len(prior) - np.searchsorted(prior, arrivals, side="right"))
        # Chunk members j < i with completion_j > arrival_i: completions
        # are monotone, so every counted entry precedes i (min-clip
        # handles the completion == arrival equality edge exactly).
        if len(arrivals) > len(self._idx):
            self._idx = np.arange(len(arrivals))
        idx = self._idx[:len(arrivals)]
        intra_done = np.searchsorted(completions, arrivals, side="right")
        depths = depths + idx - np.minimum(intra_done, idx)
        # Re-arm the heap: everything <= the chunk's last arrival can
        # never be counted again (arrivals are monotone run-wide).
        last = arrivals[-1]
        merged = np.concatenate([prior[prior > last],
                                 completions[completions > last]])
        self._heap = merged.tolist()
        heapq.heapify(self._heap)
        return depths


def _chunk_ledger(arrivals_chunk: Optional[np.ndarray],
                  occupancy: np.ndarray,
                  free_at: float) -> Tuple[np.ndarray, np.ndarray, float]:
    """Vectorized admission for one steady chunk.

    Returns ``(arrival, start, new_free_at)`` replicating the scalar
    recursion ``start_i = max(arrival_i, free_{i-1})``,
    ``free_i = start_i + occupancy_i``.  The closed loop (``arrivals_chunk
    is None``) uses a prepended cumsum so every floating-point addition
    happens in the same order as the scalar tick — bit-identical traces.
    The open loop uses the max-plus closed form
    (``np.maximum.accumulate``), exact up to float re-association.
    """
    if arrivals_chunk is None:
        # arrival_i = ready_i = free_{i-1}; start = arrival.
        c = np.cumsum(np.concatenate(([free_at], occupancy)))
        start = c[:-1]
        return start, start, float(c[-1])
    # start_i = O_i + max(free_at, max_{j<=i}(arrival_j - O_j)) with
    # O the exclusive prefix sum of occupancies.
    excl = np.concatenate(([0.0], np.cumsum(occupancy)[:-1]))
    base = np.maximum.accumulate(arrivals_chunk - excl)
    start = np.maximum(base, free_at) + excl
    return arrivals_chunk, start, float(start[-1] + occupancy[-1])


class PipelineRunner:
    """The event loop's state machine, driveable all-at-once or per query.

    One runner = one pipeline's serving window: it owns the admission
    ledger (``free_at`` / ``drain_at`` / in-system completions), the
    per-query result arrays, and the runtime-counter snapshots that make
    the finished :class:`PipelineTrace` report *this run's* rebalance
    accounting.

    Two driving modes share every line of tick code:

    * :meth:`run` — the single-pipeline driver behind
      :func:`run_pipeline`: consumes a whole arrival array, using the
      batch-granular fast path where the executor supports it.
    * :meth:`step` — feed exactly one query (the next one) with an
      explicit arrival time; used by :class:`repro.cluster.Cluster`,
      which interleaves routing decisions between queries and therefore
      cannot hand the loop the whole arrival stream upfront.

    ``capacity`` sizes the initial result arrays; serving past it grows
    them by doubling (a cluster pre-sizes each replica's runner at its
    *expected* share, not the whole fleet), and :meth:`finish` trims to
    the number actually served.
    """

    def __init__(self, executor: QueryExecutor,
                 runtime: RebalanceRuntime,
                 capacity: int,
                 chunking: bool = True,
                 max_chunk: Optional[int] = None):
        self.executor = executor
        self.runtime = runtime
        self.capacity = max(1, int(capacity))

        self._rebalances0 = runtime.num_rebalances
        self._trials0 = runtime.total_trials
        self._mitigations0 = len(runtime.mitigation_lengths)
        self._has_reference = hasattr(executor, "reference_throughput")

        mode = getattr(executor, "batch_mode", None) if chunking else None
        if mode is not None and not callable(getattr(executor,
                                                     "execute_many", None)):
            mode = None
        if mode not in (None, "vector", "batch"):
            raise ValueError(f"unknown executor batch_mode {mode!r}; "
                             f"expected 'vector', 'batch' or None")
        if mode is not None and not callable(getattr(executor,
                                                     "steady_horizon", None)):
            raise ValueError("a batching executor must provide "
                             "steady_horizon(q); chunks must not cross an "
                             "interference edge")
        self._mode = mode
        cap = (max_chunk if max_chunk is not None
               else getattr(executor, "max_chunk", DEFAULT_MAX_CHUNK))
        self._chunk_cap = max(1, int(cap))
        # "vector" chunks poll the scheduler once per environment-steady
        # segment, which is only equivalent to per-query polling when the
        # policy's steady detect is stable (pure under unchanged
        # conditions).
        self._poll_once = mode == "vector" and runtime.steady_poll_stable()

        n = self.capacity
        self.latencies = np.zeros(n)
        self.service_lat = np.zeros(n)
        self.queue_delay = np.zeros(n)
        self.throughputs = np.zeros(n)
        self.serial_mask = np.zeros(n, dtype=bool)
        self.arrival_t = np.zeros(n)
        self.completion_t = np.zeros(n)
        self.queue_depth = np.zeros(n, dtype=int)
        self.rc_thr = np.zeros(n) if self._has_reference else None
        self.configs_trace: List[List[int]] = []

        self.free_at = 0.0             # when the admission head frees up
        self.drain_at = 0.0            # when every admitted query completed
        self._pending = _CompletionLedger()  # in-system completions
        self.num_served = 0            # queries executed so far

    #: Result arrays grown together when the run outlives ``capacity``.
    _ARRAYS = ("latencies", "service_lat", "queue_delay", "throughputs",
               "serial_mask", "arrival_t", "completion_t", "queue_depth",
               "rc_thr")

    def _ensure_capacity(self, n: int) -> None:
        """Grow the result arrays (doubling) to hold ``n`` queries."""
        if n <= self.capacity:
            return
        new = max(n, 2 * self.capacity)
        for name in self._ARRAYS:
            arr = getattr(self, name)
            if arr is None:
                continue
            grown = np.zeros(new, dtype=arr.dtype)
            grown[:len(arr)] = arr
            setattr(self, name, grown)
        self.capacity = new

    # -- ticks (shared by both driving modes) -------------------------------
    def _scalar_tick(self, q: int, step, arrival: Optional[float]) -> float:
        """One query through the per-query (compatibility) path.

        ``arrival = None`` means closed-loop: the query arrives exactly
        when the pipeline can take it.  Returns the completion time.
        """
        rec = self.executor.execute(q, step)
        self.throughputs[q] = rec.throughput
        self.serial_mask[q] = step.serial
        self.configs_trace.append(list(step.config))
        # A serial trial runs on the drained pipeline, so it cannot
        # start until every in-flight pipelined query has completed.
        ready = (max(self.free_at, self.drain_at) if step.serial
                 else self.free_at)
        if arrival is None:
            arrival = ready
        self.queue_depth[q] = self._pending.depth_at(arrival)
        start = max(arrival, ready)
        occupancy = (rec.service_latency if step.serial
                     else (1.0 / rec.throughput if rec.throughput > 0
                           else 0.0))
        self.free_at = start + occupancy
        completion = start + rec.service_latency
        self.drain_at = max(self.drain_at, completion)
        self._pending.push(completion)
        self.arrival_t[q] = arrival
        self.completion_t[q] = completion
        self.queue_delay[q] = start - arrival
        self.service_lat[q] = rec.service_latency
        self.latencies[q] = self.queue_delay[q] + rec.service_latency
        return completion

    def _chunk_tick(self, q0: int, steps,
                    arrivals: Optional[np.ndarray]) -> None:
        """``len(steps)`` steady queries through ``execute_many``."""
        n = len(steps)
        sl = slice(q0, q0 + n)
        rec = self.executor.execute_many(q0, steps)
        if len(rec.throughputs) != n:
            raise ValueError(f"execute_many returned {len(rec.throughputs)} "
                             f"records for a chunk of {n}")
        self.throughputs[sl] = rec.throughputs
        if steps[0] is steps[-1]:
            # poll-once chunks replicate one step: share one row object
            # instead of materializing n copies (entries are read-only
            # by convention; the scalar path appends fresh lists).
            self.configs_trace.extend([list(steps[0].config)] * n)
        else:
            self.configs_trace.extend(list(s.config) for s in steps)
        occ = np.where(rec.throughputs > 0, 1.0 / rec.throughputs, 0.0)
        arr_chunk = arrivals[sl] if arrivals is not None else None
        arrival, start, self.free_at = _chunk_ledger(arr_chunk, occ,
                                                     self.free_at)
        completion = start + rec.service_latencies
        self.queue_depth[sl] = self._pending.depths_bulk(arrival, completion)
        self.drain_at = max(self.drain_at, float(completion[-1]))
        self.arrival_t[sl] = arrival
        self.completion_t[sl] = completion
        self.queue_delay[sl] = start - arrival
        self.service_lat[sl] = rec.service_latencies
        self.latencies[sl] = self.queue_delay[sl] + rec.service_latencies

    # -- incremental driving (one query at a time) --------------------------
    def step(self, arrival: Optional[float] = None) -> float:
        """Serve the next query, arriving at ``arrival`` (None = the
        instant this pipeline can take it — closed loop).

        The per-query semantics are identical to :meth:`run`'s scalar
        path: advance the environment, poll the scheduler runtime,
        execute, account the arrival ledger.  Returns the query's
        completion time, which callers (the cluster's routers) use for
        outstanding-work accounting.
        """
        q = self.num_served
        self._ensure_capacity(q + 1)
        source = self.executor.begin_query(q)
        if self.rc_thr is not None:
            self.rc_thr[q] = self.executor.reference_throughput(q)
        step = (self.runtime.poll(source) if source is not None
                else self.runtime.steady_step())
        completion = self._scalar_tick(q, step, arrival)
        self.num_served = q + 1
        return completion

    # -- full-run driving (the run_pipeline path) ---------------------------
    def run(self, num_queries: int,
            arrivals: Optional[np.ndarray]) -> None:
        """Serve ``num_queries`` queries with the given arrival times
        (``None`` = closed loop), using the batch-granular fast path
        where the executor supports it."""
        self._ensure_capacity(self.num_served + num_queries)
        executor, runtime = self.executor, self.runtime
        mode, cap = self._mode, self._chunk_cap
        rc_thr = self.rc_thr
        end = self.num_served + num_queries

        q = self.num_served
        while q < end:
            # -- advance the environment; poll the scheduler runtime ------
            source = executor.begin_query(q)
            if rc_thr is not None:
                rc_thr[q] = executor.reference_throughput(q)
            step = runtime.poll(source) if source is not None \
                else runtime.steady_step()

            if mode is None or step.serial:
                self._scalar_tick(
                    q, step,
                    arrivals[q] if arrivals is not None else None)
                q += 1
                continue

            if mode == "batch":
                # A real batch only forms from queries already queued at
                # dispatch time; don't pay the steady-horizon scan (up to
                # max_chunk schedule evaluations) when there is no
                # backlog.
                dispatch_t = (max(self.free_at, arrivals[q])
                              if arrivals is not None else self.free_at)
                if (arrivals is None or q + 1 >= end
                        or arrivals[q + 1] > dispatch_t):
                    self._chunk_tick(q, [step], arrivals)
                    q += 1
                    continue

            limit = min(end - q,
                        cap,
                        max(1, int(executor.steady_horizon(q))))

            if self._poll_once:
                # One poll covers the whole environment-steady segment:
                # the policy's detect is pure under unchanged (config,
                # stage times), so queries q+1 .. q+limit-1 would poll
                # identically.
                n = limit
                if rc_thr is not None:
                    rc_thr[q:q + n] = rc_thr[q]
                self._chunk_tick(q, [step] * n, arrivals)
                q += n
                continue

            # Per-query polling ("batch" mode, or "vector" with a
            # stateful detector): accumulate steady same-config queries,
            # stopping at the steady horizon, the chunk cap, a detector
            # trigger, a config change, or — for real batches — the
            # arrival backlog (a query that has not arrived by dispatch
            # time cannot join).
            steps = [step]
            leftover = None          # (q, step) polled but not chunk-able
            dispatch_t = (max(self.free_at, arrivals[q])
                          if arrivals is not None else self.free_at)
            j = q + 1
            while j < q + limit:
                if mode == "batch" and (arrivals is None
                                        or arrivals[j] > dispatch_t):
                    break
                src_j = executor.begin_query(j)
                if rc_thr is not None:
                    rc_thr[j] = executor.reference_throughput(j)
                step_j = runtime.poll(src_j) if src_j is not None \
                    else runtime.steady_step()
                if step_j.serial or step_j.config != step.config:
                    leftover = (j, step_j)
                    break
                steps.append(step_j)
                j += 1
            self._chunk_tick(q, steps, arrivals)
            q += len(steps)
            if leftover is not None:
                # Already polled (the trial/commit is charged to this
                # query); execute it without re-advancing the runtime.
                jq, jstep = leftover
                self._scalar_tick(
                    jq, jstep,
                    arrivals[jq] if arrivals is not None else None)
                q += 1
        self.num_served = q

    # -- result --------------------------------------------------------------
    def finish(self, scheduler_name: str = "",
               workload_name: str = "closed",
               peak_throughput: float = float("nan")) -> PipelineTrace:
        """Freeze the run into a :class:`PipelineTrace` (arrays trimmed
        to the number of queries actually served)."""
        n = self.num_served
        return PipelineTrace(
            scheduler=scheduler_name,
            latencies=self.latencies[:n],
            throughputs=self.throughputs[:n],
            serial_mask=self.serial_mask[:n],
            configs_trace=self.configs_trace,
            num_rebalances=self.runtime.num_rebalances - self._rebalances0,
            total_trials=self.runtime.total_trials - self._trials0,
            mitigation_lengths=list(
                self.runtime.mitigation_lengths[self._mitigations0:]),
            workload=workload_name,
            service_latencies=self.service_lat[:n],
            queue_delays=self.queue_delay[:n],
            arrival_times=self.arrival_t[:n],
            completion_times=self.completion_t[:n],
            queue_depths=self.queue_depth[:n],
            peak_throughput=peak_throughput,
            rc_throughputs=(self.rc_thr[:n] if self.rc_thr is not None
                            else None),
        )


def run_pipeline(executor: QueryExecutor,
                 runtime: RebalanceRuntime,
                 num_queries: int,
                 workload: Union[str, Workload, None] = "closed",
                 workload_kwargs: Optional[dict] = None,
                 scheduler_name: str = "",
                 peak_throughput: float = float("nan"),
                 chunking: bool = True,
                 max_chunk: Optional[int] = None) -> PipelineTrace:
    """Serve ``num_queries`` arrivals of ``workload`` through one
    scheduler runtime; returns the unified :class:`PipelineTrace`.

    ``runtime`` counters are snapshotted so the trace reports *this
    run's* rebalance accounting even when a runtime is reused across
    serving windows (the live engine's pattern).

    ``chunking=False`` forces the scalar per-query tick even when the
    executor supports ``execute_many`` (benchmark baseline / debugging);
    ``max_chunk`` overrides the executor's preferred chunk cap.
    """
    wl_name, arrivals = resolve_arrivals(workload, workload_kwargs,
                                         num_queries)
    # Executors whose interference timeline is wall-clock anchored
    # (time-indexed events, docs/CLUSTER.md) need each query's arrival
    # time to advance the environment.
    announce = getattr(executor, "set_arrivals", None)
    if callable(announce):
        announce(arrivals)

    runner = PipelineRunner(executor, runtime, num_queries,
                            chunking=chunking, max_chunk=max_chunk)
    runner.run(num_queries, arrivals)
    return runner.finish(scheduler_name=scheduler_name,
                         workload_name=wl_name,
                         peak_throughput=peak_throughput)
