"""The one traffic-driven event loop shared by simulator and live engine.

``run_pipeline`` owns the per-query tick that ``simulate()`` and
``ServingEngine.serve()`` used to hand-roll separately: advance the
environment (interference events / slowdown schedules) via the
executor, poll the shared :class:`RebalanceRuntime` for the
configuration the query must run with, execute the query through the
driver's :class:`~repro.workloads.base.QueryExecutor`, and keep the
arrival-queue ledger that turns a :class:`~repro.workloads.base.Workload`
into per-query queueing delays and offered-vs-achieved load.

Queueing model: the pipeline admits one query per bottleneck beat.  A
pipelined query holds the admission head for ``1 / throughput`` (the
bottleneck stage time) and completes ``service_latency`` after it
starts; a serial (exploration-trial) query drains the pipeline and
holds the head for its full serial latency.  Closed-loop workloads
arrive exactly when the head frees up — zero queue delay, bit-identical
to the pre-workloads drivers.  Open-loop workloads arrive on their own
clock; when arrivals outpace admission, queries wait and
``latency = queue_delay + service_latency``.

Batch-granular fast path (docs/WORKLOADS.md "Batching & the fast
path"): executors that provide ``execute_many`` are driven in *chunks*
whenever the runtime is steady — no exploration phase in flight and no
detector transition pending.  A chunk never crosses a
rebalance-relevant boundary: an interference-event edge (the
executor's ``steady_horizon``), a detector trigger, a configuration
change, or the chunk cap.  Two flavors share the code:

* ``batch_mode = "vector"`` — the chunk is a pure computational
  speedup (the simulator): the scheduler is polled once per
  environment-steady segment (valid when the policy advertises
  ``steady_detect_stable``) and the whole arrival/queue/completion
  ledger is computed with vectorized numpy instead of the scalar tick.
* ``batch_mode = "batch"`` — the chunk is a *real* batch (the live
  engine): the scheduler is still polled per query, but queries that
  have already arrived are stacked and executed together, so a burst
  pays one set of stage dispatches instead of one per query.
"""
from __future__ import annotations

import heapq
from typing import TYPE_CHECKING, List, Optional, Tuple, Union

import numpy as np

from repro.workloads.base import QueryExecutor, Workload
from repro.workloads.registry import make_workload
from repro.workloads.trace import PipelineTrace

if TYPE_CHECKING:  # annotation-only: keeps workloads <-> schedulers acyclic
    from repro.schedulers.runtime import RebalanceRuntime

#: Fallback chunk cap when the executor does not prefer one.  Bounds the
#: temporary per-chunk arrays; segments longer than this simply split.
DEFAULT_MAX_CHUNK = 4096


def resolve_workload(workload: Union[str, Workload, None],
                     workload_kwargs: Optional[dict] = None) -> Workload:
    """Name (+ kwargs) or instance -> Workload instance."""
    if workload is None:
        workload = "closed"
    if isinstance(workload, str):
        return make_workload(workload, **(workload_kwargs or {}))
    if workload_kwargs:
        raise ValueError("workload_kwargs only apply to a workload name, "
                         "not an already-constructed instance")
    return workload


class _CompletionLedger:
    """Completion times of admitted-but-unfinished queries.

    Replaces the old never-pruned ``bisect.insort`` list (O(n²) time and
    O(n) memory over a run) with a pruned min-heap: arrivals are
    monotone, so any completion ``<= arrival`` can never be counted by a
    later depth query and is dropped as the run advances — million-query
    runs stay O(n log n) with flat memory (the heap holds only the
    in-system queries, ~pipeline depth).
    """

    def __init__(self):
        self._heap: List[float] = []
        self._idx = np.arange(256)     # grown on demand, reused per chunk

    def depth_at(self, arrival: float) -> int:
        """In-system depth seen by an arrival (completions > arrival)."""
        heap = self._heap
        while heap and heap[0] <= arrival:
            heapq.heappop(heap)
        return len(heap)

    def push(self, completion: float) -> None:
        heapq.heappush(self._heap, completion)

    def depths_bulk(self, arrivals: np.ndarray,
                    completions: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`depth_at` + :meth:`push` for one chunk.

        ``arrivals`` and ``completions`` are the chunk's index-aligned
        ledger arrays; both are non-decreasing (chunks are
        environment-steady).  Depth ``i`` counts prior in-flight
        completions plus chunk members ``j < i`` still in flight.
        """
        if len(completions) > 1:
            dec = completions[:-1] - completions[1:]
            # Executors attribute per-query times with float arithmetic
            # whose rounding can wiggle mathematically-equal completions
            # by an ulp; only a *real* decrease breaks the contract.
            if bool(np.any(dec > 1e-9 * np.abs(completions[:-1]))):
                raise ValueError(
                    "chunk completion times must be non-decreasing")
            # Identity for truly monotone chunks (the simulator's — its
            # bit-exactness is untouched); irons out ulp wiggles so the
            # binary searches below stay well-defined.
            completions = np.maximum.accumulate(completions)
        prior = np.sort(self._heap) if self._heap else np.empty(0)
        depths = (len(prior) - np.searchsorted(prior, arrivals, side="right"))
        # Chunk members j < i with completion_j > arrival_i: completions
        # are monotone, so every counted entry precedes i (min-clip
        # handles the completion == arrival equality edge exactly).
        if len(arrivals) > len(self._idx):
            self._idx = np.arange(len(arrivals))
        idx = self._idx[:len(arrivals)]
        intra_done = np.searchsorted(completions, arrivals, side="right")
        depths = depths + idx - np.minimum(intra_done, idx)
        # Re-arm the heap: everything <= the chunk's last arrival can
        # never be counted again (arrivals are monotone run-wide).
        last = arrivals[-1]
        merged = np.concatenate([prior[prior > last],
                                 completions[completions > last]])
        self._heap = merged.tolist()
        heapq.heapify(self._heap)
        return depths


def _chunk_ledger(arrivals_chunk: Optional[np.ndarray],
                  occupancy: np.ndarray,
                  free_at: float) -> Tuple[np.ndarray, np.ndarray, float]:
    """Vectorized admission for one steady chunk.

    Returns ``(arrival, start, new_free_at)`` replicating the scalar
    recursion ``start_i = max(arrival_i, free_{i-1})``,
    ``free_i = start_i + occupancy_i``.  The closed loop (``arrivals_chunk
    is None``) uses a prepended cumsum so every floating-point addition
    happens in the same order as the scalar tick — bit-identical traces.
    The open loop uses the max-plus closed form
    (``np.maximum.accumulate``), exact up to float re-association.
    """
    if arrivals_chunk is None:
        # arrival_i = ready_i = free_{i-1}; start = arrival.
        c = np.cumsum(np.concatenate(([free_at], occupancy)))
        start = c[:-1]
        return start, start, float(c[-1])
    # start_i = O_i + max(free_at, max_{j<=i}(arrival_j - O_j)) with
    # O the exclusive prefix sum of occupancies.
    excl = np.concatenate(([0.0], np.cumsum(occupancy)[:-1]))
    base = np.maximum.accumulate(arrivals_chunk - excl)
    start = np.maximum(base, free_at) + excl
    return arrivals_chunk, start, float(start[-1] + occupancy[-1])


def run_pipeline(executor: QueryExecutor,
                 runtime: RebalanceRuntime,
                 num_queries: int,
                 workload: Union[str, Workload, None] = "closed",
                 workload_kwargs: Optional[dict] = None,
                 scheduler_name: str = "",
                 peak_throughput: float = float("nan"),
                 chunking: bool = True,
                 max_chunk: Optional[int] = None) -> PipelineTrace:
    """Serve ``num_queries`` arrivals of ``workload`` through one
    scheduler runtime; returns the unified :class:`PipelineTrace`.

    ``runtime`` counters are snapshotted so the trace reports *this
    run's* rebalance accounting even when a runtime is reused across
    serving windows (the live engine's pattern).

    ``chunking=False`` forces the scalar per-query tick even when the
    executor supports ``execute_many`` (benchmark baseline / debugging);
    ``max_chunk`` overrides the executor's preferred chunk cap.
    """
    wl = resolve_workload(workload, workload_kwargs)
    wl_name = getattr(wl, "name", type(wl).__name__)
    gaps = wl.inter_arrivals(num_queries) if wl.open_loop else None
    if gaps is not None and len(gaps) != num_queries:
        raise ValueError(f"workload {wl_name!r} produced {len(gaps)} "
                         f"inter-arrivals for {num_queries} queries")
    arrivals = np.cumsum(gaps) if gaps is not None else None

    rebalances0 = runtime.num_rebalances
    trials0 = runtime.total_trials
    mitigations0 = len(runtime.mitigation_lengths)
    has_reference = hasattr(executor, "reference_throughput")

    mode = getattr(executor, "batch_mode", None) if chunking else None
    if mode is not None and not callable(getattr(executor, "execute_many",
                                                 None)):
        mode = None
    if mode not in (None, "vector", "batch"):
        raise ValueError(f"unknown executor batch_mode {mode!r}; "
                         f"expected 'vector', 'batch' or None")
    if mode is not None and not callable(getattr(executor, "steady_horizon",
                                                 None)):
        raise ValueError("a batching executor must provide "
                         "steady_horizon(q); chunks must not cross an "
                         "interference edge")
    cap = (max_chunk if max_chunk is not None
           else getattr(executor, "max_chunk", DEFAULT_MAX_CHUNK))
    cap = max(1, int(cap))
    # "vector" chunks poll the scheduler once per environment-steady
    # segment, which is only equivalent to per-query polling when the
    # policy's steady detect is stable (pure under unchanged conditions).
    poll_once = mode == "vector" and runtime.steady_poll_stable()

    latencies = np.zeros(num_queries)
    service_lat = np.zeros(num_queries)
    queue_delay = np.zeros(num_queries)
    throughputs = np.zeros(num_queries)
    serial_mask = np.zeros(num_queries, dtype=bool)
    arrival_t = np.zeros(num_queries)
    completion_t = np.zeros(num_queries)
    queue_depth = np.zeros(num_queries, dtype=int)
    rc_thr = np.zeros(num_queries) if has_reference else None
    configs_trace: List[List[int]] = []

    free_at = 0.0                  # when the admission head frees up
    drain_at = 0.0                 # when every admitted query has completed
    pending = _CompletionLedger()  # completions of in-system queries

    def scalar_tick(q, step):
        """One query through the per-query (compatibility) path."""
        nonlocal free_at, drain_at
        rec = executor.execute(q, step)
        throughputs[q] = rec.throughput
        serial_mask[q] = step.serial
        configs_trace.append(list(step.config))
        # A serial trial runs on the drained pipeline, so it cannot
        # start until every in-flight pipelined query has completed.
        ready = max(free_at, drain_at) if step.serial else free_at
        arrival = arrivals[q] if arrivals is not None else ready
        queue_depth[q] = pending.depth_at(arrival)
        start = max(arrival, ready)
        occupancy = (rec.service_latency if step.serial
                     else (1.0 / rec.throughput if rec.throughput > 0
                           else 0.0))
        free_at = start + occupancy
        completion = start + rec.service_latency
        drain_at = max(drain_at, completion)
        pending.push(completion)
        arrival_t[q] = arrival
        completion_t[q] = completion
        queue_delay[q] = start - arrival
        service_lat[q] = rec.service_latency
        latencies[q] = queue_delay[q] + rec.service_latency

    def chunk_tick(q0, steps):
        """``len(steps)`` steady queries through ``execute_many``."""
        nonlocal free_at, drain_at
        n = len(steps)
        sl = slice(q0, q0 + n)
        rec = executor.execute_many(q0, steps)
        if len(rec.throughputs) != n:
            raise ValueError(f"execute_many returned {len(rec.throughputs)} "
                             f"records for a chunk of {n}")
        throughputs[sl] = rec.throughputs
        if steps[0] is steps[-1]:
            # poll-once chunks replicate one step: share one row object
            # instead of materializing n copies (entries are read-only
            # by convention; the scalar path appends fresh lists).
            configs_trace.extend([list(steps[0].config)] * n)
        else:
            configs_trace.extend(list(s.config) for s in steps)
        occ = np.where(rec.throughputs > 0, 1.0 / rec.throughputs, 0.0)
        arr_chunk = arrivals[sl] if arrivals is not None else None
        arrival, start, free_at = _chunk_ledger(arr_chunk, occ, free_at)
        completion = start + rec.service_latencies
        queue_depth[sl] = pending.depths_bulk(arrival, completion)
        drain_at = max(drain_at, float(completion[-1]))
        arrival_t[sl] = arrival
        completion_t[sl] = completion
        queue_delay[sl] = start - arrival
        service_lat[sl] = rec.service_latencies
        latencies[sl] = queue_delay[sl] + rec.service_latencies

    q = 0
    while q < num_queries:
        # -- advance the environment; poll the scheduler runtime ----------
        source = executor.begin_query(q)
        if rc_thr is not None:
            rc_thr[q] = executor.reference_throughput(q)
        step = runtime.poll(source) if source is not None \
            else runtime.steady_step()

        if mode is None or step.serial:
            scalar_tick(q, step)
            q += 1
            continue

        if mode == "batch":
            # A real batch only forms from queries already queued at
            # dispatch time; don't pay the steady-horizon scan (up to
            # max_chunk schedule evaluations) when there is no backlog.
            dispatch_t = (max(free_at, arrivals[q]) if arrivals is not None
                          else free_at)
            if (arrivals is None or q + 1 >= num_queries
                    or arrivals[q + 1] > dispatch_t):
                chunk_tick(q, [step])
                q += 1
                continue

        limit = min(num_queries - q,
                    cap,
                    max(1, int(executor.steady_horizon(q))))

        if poll_once:
            # One poll covers the whole environment-steady segment: the
            # policy's detect is pure under unchanged (config, stage
            # times), so queries q+1 .. q+limit-1 would poll identically.
            n = limit
            if rc_thr is not None:
                rc_thr[q:q + n] = rc_thr[q]
            chunk_tick(q, [step] * n)
            q += n
            continue

        # Per-query polling ("batch" mode, or "vector" with a stateful
        # detector): accumulate steady same-config queries, stopping at
        # the steady horizon, the chunk cap, a detector trigger, a
        # config change, or — for real batches — the arrival backlog
        # (a query that has not arrived by dispatch time cannot join).
        steps = [step]
        leftover = None              # (q, step) polled but not chunk-able
        dispatch_t = (max(free_at, arrivals[q]) if arrivals is not None
                      else free_at)
        j = q + 1
        while j < q + limit:
            if mode == "batch" and (arrivals is None
                                    or arrivals[j] > dispatch_t):
                break
            src_j = executor.begin_query(j)
            if rc_thr is not None:
                rc_thr[j] = executor.reference_throughput(j)
            step_j = runtime.poll(src_j) if src_j is not None \
                else runtime.steady_step()
            if step_j.serial or step_j.config != step.config:
                leftover = (j, step_j)
                break
            steps.append(step_j)
            j += 1
        chunk_tick(q, steps)
        q += len(steps)
        if leftover is not None:
            # Already polled (the trial/commit is charged to this
            # query); execute it without re-advancing the runtime.
            scalar_tick(*leftover)
            q += 1

    return PipelineTrace(
        scheduler=scheduler_name,
        latencies=latencies,
        throughputs=throughputs,
        serial_mask=serial_mask,
        configs_trace=configs_trace,
        num_rebalances=runtime.num_rebalances - rebalances0,
        total_trials=runtime.total_trials - trials0,
        mitigation_lengths=list(runtime.mitigation_lengths[mitigations0:]),
        workload=wl_name,
        service_latencies=service_lat,
        queue_delays=queue_delay,
        arrival_times=arrival_t,
        completion_times=completion_t,
        queue_depths=queue_depth,
        peak_throughput=peak_throughput,
        rc_throughputs=rc_thr,
    )
