"""The one traffic-driven event loop shared by simulator and live engine.

``run_pipeline`` owns the per-query tick that ``simulate()`` and
``ServingEngine.serve()`` used to hand-roll separately: advance the
environment (interference events / slowdown schedules) via the
executor, poll the shared :class:`RebalanceRuntime` for the
configuration the query must run with, execute the query through the
driver's :class:`~repro.workloads.base.QueryExecutor`, and keep the
arrival-queue ledger that turns a :class:`~repro.workloads.base.Workload`
into per-query queueing delays and offered-vs-achieved load.

Queueing model: the pipeline admits one query per bottleneck beat.  A
pipelined query holds the admission head for ``1 / throughput`` (the
bottleneck stage time) and completes ``service_latency`` after it
starts; a serial (exploration-trial) query drains the pipeline and
holds the head for its full serial latency.  Closed-loop workloads
arrive exactly when the head frees up — zero queue delay, bit-identical
to the pre-workloads drivers.  Open-loop workloads arrive on their own
clock; when arrivals outpace admission, queries wait and
``latency = queue_delay + service_latency``.
"""
from __future__ import annotations

import bisect
from typing import TYPE_CHECKING, List, Optional, Union

import numpy as np

from repro.workloads.base import QueryExecutor, Workload
from repro.workloads.registry import make_workload
from repro.workloads.trace import PipelineTrace

if TYPE_CHECKING:  # annotation-only: keeps workloads <-> schedulers acyclic
    from repro.schedulers.runtime import RebalanceRuntime


def resolve_workload(workload: Union[str, Workload, None],
                     workload_kwargs: Optional[dict] = None) -> Workload:
    """Name (+ kwargs) or instance -> Workload instance."""
    if workload is None:
        workload = "closed"
    if isinstance(workload, str):
        return make_workload(workload, **(workload_kwargs or {}))
    if workload_kwargs:
        raise ValueError("workload_kwargs only apply to a workload name, "
                         "not an already-constructed instance")
    return workload


def run_pipeline(executor: QueryExecutor,
                 runtime: RebalanceRuntime,
                 num_queries: int,
                 workload: Union[str, Workload, None] = "closed",
                 workload_kwargs: Optional[dict] = None,
                 scheduler_name: str = "",
                 peak_throughput: float = float("nan")) -> PipelineTrace:
    """Serve ``num_queries`` arrivals of ``workload`` through one
    scheduler runtime; returns the unified :class:`PipelineTrace`.

    ``runtime`` counters are snapshotted so the trace reports *this
    run's* rebalance accounting even when a runtime is reused across
    serving windows (the live engine's pattern).
    """
    wl = resolve_workload(workload, workload_kwargs)
    wl_name = getattr(wl, "name", type(wl).__name__)
    gaps = wl.inter_arrivals(num_queries) if wl.open_loop else None
    if gaps is not None and len(gaps) != num_queries:
        raise ValueError(f"workload {wl_name!r} produced {len(gaps)} "
                         f"inter-arrivals for {num_queries} queries")
    arrivals = np.cumsum(gaps) if gaps is not None else None

    rebalances0 = runtime.num_rebalances
    trials0 = runtime.total_trials
    mitigations0 = len(runtime.mitigation_lengths)
    has_reference = hasattr(executor, "reference_throughput")

    latencies = np.zeros(num_queries)
    service_lat = np.zeros(num_queries)
    queue_delay = np.zeros(num_queries)
    throughputs = np.zeros(num_queries)
    serial_mask = np.zeros(num_queries, dtype=bool)
    arrival_t = np.zeros(num_queries)
    completion_t = np.zeros(num_queries)
    queue_depth = np.zeros(num_queries, dtype=int)
    rc_thr = np.zeros(num_queries) if has_reference else None
    configs_trace: List[List[int]] = []

    free_at = 0.0                  # when the admission head frees up
    drain_at = 0.0                 # when every admitted query has completed
    pending: List[float] = []      # completion times of admitted queries

    for q in range(num_queries):
        # -- advance the environment; poll the scheduler runtime ----------
        source = executor.begin_query(q)
        if rc_thr is not None:
            rc_thr[q] = executor.reference_throughput(q)
        step = runtime.poll(source) if source is not None \
            else runtime.steady_step()

        # -- execute the query -------------------------------------------
        rec = executor.execute(q, step)
        throughputs[q] = rec.throughput
        serial_mask[q] = step.serial
        configs_trace.append(list(step.config))

        # -- arrival-queue ledger ----------------------------------------
        # A serial trial runs on the drained pipeline, so it cannot start
        # until every in-flight pipelined query has completed.
        ready = max(free_at, drain_at) if step.serial else free_at
        arrival = arrivals[q] if arrivals is not None else ready
        # In-system depth at this arrival: admitted or waiting queries
        # that have not yet completed (a full pipeline holds ~N).
        queue_depth[q] = len(pending) - bisect.bisect_right(pending, arrival)
        start = max(arrival, ready)
        occupancy = (rec.service_latency if step.serial
                     else (1.0 / rec.throughput if rec.throughput > 0
                           else 0.0))
        free_at = start + occupancy
        completion = start + rec.service_latency
        drain_at = max(drain_at, completion)
        bisect.insort(pending, completion)

        arrival_t[q] = arrival
        completion_t[q] = completion
        queue_delay[q] = start - arrival
        service_lat[q] = rec.service_latency
        latencies[q] = queue_delay[q] + rec.service_latency

    return PipelineTrace(
        scheduler=scheduler_name,
        latencies=latencies,
        throughputs=throughputs,
        serial_mask=serial_mask,
        configs_trace=configs_trace,
        num_rebalances=runtime.num_rebalances - rebalances0,
        total_trials=runtime.total_trials - trials0,
        mitigation_lengths=list(runtime.mitigation_lengths[mitigations0:]),
        workload=wl_name,
        service_latencies=service_lat,
        queue_delays=queue_delay,
        arrival_times=arrival_t,
        completion_times=completion_t,
        queue_depths=queue_depth,
        peak_throughput=peak_throughput,
        rc_throughputs=rc_thr,
    )
