"""Per-query sequence-length distributions (docs/WORKLOADS.md).

Arrival processes say *when* queries show up; length samplers say *how
big* each one is.  Real traffic mixes short and long prompts, and the
mix is what makes batching policy interesting: one straggler length
pads everyone unless dispatch groups by length bucket
(``repro.workloads.batching``).

All samplers are seeded and deterministic — calling ``sample`` twice
returns the identical integer array, so a run is reproducible from
``(sampler name, kwargs, seed)`` alone, mirroring the arrival
generators.

* ``fixed`` — every query at one length (the pre-lengths behaviour).
* ``uniform`` — integer-uniform lengths in ``[lo, hi]``.
* ``bimodal`` — short/long mixture: length ``long`` with probability
  ``p_long``, else ``short`` (the classic chat-vs-document split).
* ``trace`` — replays a recorded per-query length array, cycled when
  the run outlasts the trace.

``resolve_lengths`` is the one construction path drivers use: it
accepts a sampler name, a sampler instance, an explicit array, or
``None`` (in which case a workload carrying its own ``query_lengths``
hook — see :func:`with_lengths` — is consulted).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Type

import numpy as np

_LENGTHS: Dict[str, Type] = {}


def register_lengths(name: str) -> Callable[[Type], Type]:
    """Class decorator registering a length sampler under ``name``."""
    def deco(cls: Type) -> Type:
        if name in _LENGTHS:
            raise ValueError(f"length sampler {name!r} already registered")
        _LENGTHS[name] = cls
        return cls
    return deco


def available_lengths() -> List[str]:
    """Sorted names of every registered length sampler."""
    return sorted(_LENGTHS)


def make_lengths(name: str, **kwargs):
    """Construct the length sampler registered under ``name``."""
    if name not in _LENGTHS:
        raise ValueError(f"unknown length sampler {name!r}; "
                         f"available: {available_lengths()}")
    return _LENGTHS[name](**kwargs)


@register_lengths("fixed")
class FixedLengths:
    """Every query at one sequence length."""

    def __init__(self, length: int):
        if length < 1:
            raise ValueError(f"length must be >= 1, got {length}")
        self.length = int(length)

    def sample(self, num_queries: int) -> np.ndarray:
        return np.full(num_queries, self.length, dtype=np.int64)


@register_lengths("uniform")
class UniformLengths:
    """Integer-uniform lengths in ``[lo, hi]`` inclusive."""

    def __init__(self, lo: int, hi: int, seed: int = 0):
        if lo < 1 or hi < lo:
            raise ValueError(f"need 1 <= lo <= hi, got [{lo}, {hi}]")
        self.lo, self.hi, self.seed = int(lo), int(hi), int(seed)

    def sample(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.integers(self.lo, self.hi + 1, size=num_queries,
                            dtype=np.int64)


@register_lengths("bimodal")
class BimodalLengths:
    """Short/long mixture: ``long`` with probability ``p_long``, else
    ``short`` — chat turns vs. pasted documents."""

    def __init__(self, short: int, long: int, p_long: float = 0.2,
                 seed: int = 0):
        if short < 1 or long < short:
            raise ValueError(f"need 1 <= short <= long, "
                             f"got short={short} long={long}")
        if not 0.0 <= p_long <= 1.0:
            raise ValueError(f"p_long must be in [0, 1], got {p_long}")
        self.short, self.long = int(short), int(long)
        self.p_long, self.seed = float(p_long), int(seed)

    def sample(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        is_long = rng.random(num_queries) < self.p_long
        return np.where(is_long, self.long, self.short).astype(np.int64)


@register_lengths("trace")
class TraceLengths:
    """Replays a recorded per-query length array (e.g. from production
    logs), cycling it when the run outlasts the trace."""

    def __init__(self, lengths: Sequence[int]):
        arr = np.asarray(lengths, dtype=np.int64)
        if arr.ndim != 1 or len(arr) == 0:
            raise ValueError("lengths must be a non-empty 1-D array")
        if np.any(arr < 1):
            raise ValueError("lengths must be >= 1")
        self.lengths = arr

    def sample(self, num_queries: int) -> np.ndarray:
        reps = -(-num_queries // len(self.lengths))     # ceil division
        return np.tile(self.lengths, reps)[:num_queries]


class _LengthsWorkload:
    """A workload wrapper carrying a per-query length distribution."""

    def __init__(self, workload, sampler):
        self._workload = workload
        self._sampler = sampler
        self.open_loop = workload.open_loop

    def inter_arrivals(self, num_queries: int):
        return self._workload.inter_arrivals(num_queries)

    def query_lengths(self, num_queries: int) -> np.ndarray:
        return self._sampler.sample(num_queries)


def with_lengths(workload, sampler):
    """Attach a length sampler to any arrival workload.

    The returned workload forwards ``open_loop`` / ``inter_arrivals``
    and additionally answers ``query_lengths(n)`` — the optional hook
    ``resolve_lengths`` consults when the driver passes no explicit
    lengths.
    """
    if isinstance(sampler, str):
        sampler = make_lengths(sampler)
    return _LengthsWorkload(workload, sampler)


def resolve_lengths(lengths, lengths_kwargs, num_queries: int,
                    workload=None) -> Optional[np.ndarray]:
    """One construction path for per-query lengths.

    ``lengths`` may be a sampler name (``lengths_kwargs`` forwarded), a
    sampler instance (anything with ``sample``), an explicit per-query
    array (cycled if shorter than the run), or ``None`` — in which case
    a workload providing ``query_lengths`` is consulted, and otherwise
    no lengths are attached (every query at the driver's nominal
    length, the pre-lengths behaviour).
    """
    if lengths is None:
        if workload is not None and hasattr(workload, "query_lengths"):
            lengths = workload.query_lengths(num_queries)
        else:
            return None
    if isinstance(lengths, str):
        lengths = make_lengths(lengths, **(lengths_kwargs or {}))
    if hasattr(lengths, "sample"):
        lengths = lengths.sample(num_queries)
    arr = np.asarray(lengths, dtype=np.int64)
    if arr.ndim != 1 or len(arr) == 0:
        raise ValueError("lengths must resolve to a non-empty 1-D array")
    if np.any(arr < 1):
        raise ValueError("query lengths must be >= 1")
    if len(arr) < num_queries:
        reps = -(-num_queries // len(arr))
        arr = np.tile(arr, reps)
    return arr[:num_queries]
