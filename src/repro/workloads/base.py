"""Workload & executor protocols for the unified run loop.

A :class:`Workload` describes *when queries arrive*; a
:class:`QueryExecutor` describes *how one query runs* (database lookups
in the simulator, real JAX execution in the live engine).  The one
:func:`~repro.workloads.runner.run_pipeline` event loop combines them
with the shared :class:`~repro.schedulers.runtime.RebalanceRuntime`, so
the simulator and the serving engine execute scheduling policies —
and report metrics — through identical code.

Time is whatever unit the executor's stage times are in: wall-clock
seconds for the live engine, database time units for the simulator.
Open-loop rates are expressed in queries per that unit.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Protocol, runtime_checkable

import numpy as np

if TYPE_CHECKING:  # annotation-only: keeps workloads import-cycle-free
    from repro.core.pipeline_state import StageTimeSource
    from repro.schedulers.runtime import RuntimeStep


@runtime_checkable
class Workload(Protocol):
    """An arrival process: closed-loop or a seeded open-loop generator."""

    #: False = closed loop: each query arrives the instant the pipeline
    #: can take it (no queueing).  True = open loop: arrivals are
    #: exogenous and queries queue when the pipeline falls behind.
    open_loop: bool

    def inter_arrivals(self, num_queries: int) -> Optional[np.ndarray]:
        """Gap before each query (same unit as the executor's times).

        Returns ``None`` for closed-loop workloads.  Must be
        deterministic: calling twice yields the identical array.
        """
        ...


@dataclasses.dataclass
class QueryRecord:
    """What one executed query reports back to the run loop."""

    #: Time the query spent in service (pipelined or serial latency);
    #: excludes any arrival-queue wait, which the run loop accounts.
    service_latency: float
    #: Pipeline capability while serving this query: 1 / bottleneck
    #: stage time.  Determines how soon the pipeline frees up for the
    #: next query when running pipelined.
    throughput: float
    #: Fraction of the bottleneck stage's time spent in collectives;
    #: 0.0 on unsharded runs (docs/SHARDING.md).
    collective_frac: float = 0.0


@dataclasses.dataclass
class BatchRecord:
    """What one executed *chunk* of queries reports back to the run loop.

    The batch-granular analogue of :class:`QueryRecord`: per-query
    arrays, index-aligned with the chunk.  Chunks are always
    environment-steady (one configuration, one interference state), so
    in practice every entry is the same value — but executors that
    attribute measured time non-uniformly may vary them, as long as the
    implied completion times stay non-decreasing (the run loop's
    vectorized ledger relies on that monotonicity).
    """

    #: Per-query time in service (excludes arrival-queue wait).
    service_latencies: np.ndarray
    #: Per-query pipeline capability.  ``1 / throughput`` is how long
    #: the query holds the admission head; a real stacked batch reports
    #: ``batch_size / bottleneck_stage_time`` for each member so the
    #: whole batch occupies the head for one bottleneck beat.
    throughputs: np.ndarray
    #: Per-query bottleneck collective share; ``None`` on unsharded
    #: runs (docs/SHARDING.md).
    collective_fracs: Optional[np.ndarray] = None

    def __post_init__(self):
        self.service_latencies = np.asarray(self.service_latencies, float)
        self.throughputs = np.asarray(self.throughputs, float)
        if self.service_latencies.shape != self.throughputs.shape:
            raise ValueError("BatchRecord arrays must be index-aligned")
        if self.collective_fracs is not None:
            self.collective_fracs = np.asarray(self.collective_fracs, float)


@dataclasses.dataclass
class DispatchRecord:
    """What one *formed dispatch* (a batch, possibly grown mid-flight by
    stage-boundary joins) reports back to the run loop.

    All offsets are relative to the dispatch start ``t0`` chosen by the
    run loop's ledger.  Every member completes together when the batch
    drains: ``completion_i = t0 + drain``; member ``i`` entered service
    at ``t0 + start_offsets[i]`` (0 for members present at dispatch,
    the join-boundary clock for continuous joiners), so its service
    latency is ``drain - start_offsets[i]`` and its queue delay is
    ``t0 + start_offsets[i] - arrival_i``.

    ``throughput`` is the dispatch-level service rate: formed dispatch
    is group-synchronous (the next dispatch launches only after this
    one retires), so ``1 / throughput`` is how long the dispatch holds
    the admission head — its full drain — and each of the ``n`` members
    reports ``n * throughput`` (n queries retired per drain).
    """

    #: Per-member service start offset from ``t0`` (non-decreasing).
    start_offsets: np.ndarray
    #: Batch completion offset from ``t0`` (all members finish here).
    drain: float
    #: Dispatch-level service rate (1 / full drain).
    throughput: float
    #: Total padded tokens executed (bucket-edge lengths x members,
    #: plus any batch-dimension padding rows); 0 when the run carries
    #: no length information.
    padded_tokens: float = 0.0
    #: Total useful tokens (actual query lengths); 0 when unknown.
    actual_tokens: float = 0.0
    #: Bottleneck collective share of the dispatch; 0.0 on unsharded
    #: runs (docs/SHARDING.md).
    collective_frac: float = 0.0

    def __post_init__(self):
        self.start_offsets = np.asarray(self.start_offsets, float)
        if self.start_offsets.ndim != 1 or len(self.start_offsets) == 0:
            raise ValueError("DispatchRecord needs >= 1 member")


class QueryExecutor(Protocol):
    """One query's environment + execution, driver-specific.

    Optionally an executor may also provide ``reference_throughput(q)
    -> float`` — the resource-constrained optimum under query ``q``'s
    interference (the simulator's DP oracle); the run loop records it
    into ``PipelineTrace.rc_throughputs`` when present.

    Executors that can service several queries at once opt into the
    run loop's batch-granular fast path by additionally providing:

    * ``batch_mode`` — ``"vector"`` (chunks are a pure computational
      speedup; per-query semantics unchanged, e.g. the simulator's
      array lookups) or ``"batch"`` (chunks are *real* batches whose
      members share one execution, e.g. the live engine stacking token
      arrays).  ``None`` / absent keeps the scalar path.
    * ``execute_many(q0, steps) -> BatchRecord`` — run queries
      ``q0 .. q0+len(steps)-1``; all steps are steady and share one
      configuration.
    * ``steady_horizon(q) -> int`` — how many queries starting at ``q``
      the environment is guaranteed constant for (same interference
      state); chunks never cross this boundary.
    * ``max_chunk`` (optional int) — executor-preferred chunk cap
      (e.g. the live engine's ``max_batch``).

    Executors that support **continuous batching** (a
    :class:`~repro.workloads.batching.BatchFormer` attached to the run)
    additionally provide ``begin_dispatch(q0, step) -> builder``, where
    the builder exposes:

    * ``add(q)`` — stack query ``q`` into the batch before it launches.
    * ``next_boundary() -> Optional[float]`` — advance the batch one
      pipeline-stage; returns the boundary's clock offset from the
      dispatch start, or ``None`` once the batch has drained.
    * ``join(q)`` — fold query ``q`` into the in-flight batch at the
      current boundary (the builder catches it up through the already-
      executed stages and accounts the delay honestly).
    * ``finish() -> DispatchRecord`` — drain and report.

    The run loop drives the builder (it owns arrivals and admission);
    the builder owns execution — analytic stage arithmetic in the
    simulator, physical ``run_stages`` calls in the live engine.
    """

    def begin_query(self, q: int) -> Optional[StageTimeSource]:
        """Advance the environment to query ``q`` (interference events /
        slowdown schedules) and return the time source the scheduler
        runtime should be polled with — or ``None`` if the policy cannot
        be consulted yet (live engine before its first measurement), in
        which case the query runs steady on the committed config."""
        ...

    def execute(self, q: int, step: RuntimeStep) -> QueryRecord:
        """Run query ``q`` with ``step.config`` and report timings."""
        ...
