"""Batch formation policy: drain vs. continuous, length buckets.

``serve(max_batch=N)``'s original stacked dispatch is *drain-and-refill*
batching: stack whatever has already arrived, run the whole batch to
completion, only then look at the queue again.  Under bursty arrivals
the queries that land just after a dispatch wait out the entire drain.

A :class:`BatchFormer` makes the policy explicit and adds **continuous
batching**: new arrivals are folded into the in-flight batch at
pipeline-stage boundaries (a joiner is caught up through the stages the
batch already passed, then rides along), so queue delay stops scaling
with the full drain time.  **Length buckets** make mixed-length traffic
batchable: each query is padded up to a small set of bucket edges
(powers of two by default), dispatch groups *contiguous same-bucket
runs* — arrival order is never reordered, which is what keeps the run
loop's vectorized completion ledger exact — and the executor pre-warms
exactly the bucket shapes.

The former is pure policy: it owns no clock and runs no queries.  The
run loop (``repro.workloads.runner``) consults it for membership
decisions; executors implement the actual joining via their
``begin_dispatch`` builders (analytic in the simulator, physical
``run_stages`` execution in the live engine).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

BATCHING_MODES = ("drain", "continuous")


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1).

    Also defined by ``repro.pipeline.executor`` — duplicated two lines
    here so the simulator never has to import the jax executor stack.
    """
    return 1 << (max(int(n), 1) - 1).bit_length()


class LengthBuckets:
    """A sorted set of sequence-length bucket edges.

    Every query is padded up to the smallest edge >= its length;
    batches only mix queries inside one bucket, so one straggler length
    never pads the whole batch to its size.  Fewer buckets = fewer
    compiled shapes but more padding waste; more buckets = tighter
    padding but batches fragment (docs/PERFORMANCE.md).
    """

    def __init__(self, edges: Sequence[int]):
        arr = np.unique(np.asarray(edges, dtype=np.int64))
        if len(arr) == 0:
            raise ValueError("LengthBuckets needs at least one edge")
        if arr[0] < 1:
            raise ValueError(f"bucket edges must be >= 1, got {list(arr)}")
        self.edges = arr

    @classmethod
    def pow2(cls, lo: int, hi: int) -> "LengthBuckets":
        """Powers-of-two edges covering ``[lo, hi]``."""
        if lo < 1 or hi < lo:
            raise ValueError(f"need 1 <= lo <= hi, got [{lo}, {hi}]")
        edges, e = [], next_pow2(lo)
        while e < hi:
            edges.append(e)
            e *= 2
        edges.append(e)
        return cls(edges)

    @classmethod
    def single(cls, seq: int) -> "LengthBuckets":
        """One bucket: every query padded to ``seq``."""
        return cls([seq])

    def pad(self, length: int) -> int:
        """Smallest bucket edge >= ``length``."""
        i = int(np.searchsorted(self.edges, length))
        if i == len(self.edges):
            raise ValueError(f"length {length} exceeds largest bucket "
                             f"edge {int(self.edges[-1])}")
        return int(self.edges[i])

    def pad_many(self, lengths: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`pad` over a length array."""
        idx = np.searchsorted(self.edges, lengths)
        if np.any(idx == len(self.edges)):
            worst = int(np.max(lengths))
            raise ValueError(f"length {worst} exceeds largest bucket "
                             f"edge {int(self.edges[-1])}")
        return self.edges[idx]

    def __repr__(self):
        return f"LengthBuckets({list(map(int, self.edges))})"


@dataclasses.dataclass
class BatchFormer:
    """Batch formation policy consumed by the run loop.

    ``mode="drain"`` stacks queued arrivals only at dispatch instants
    (the explicit spelling of the original ``max_batch`` behaviour,
    plus buckets); ``mode="continuous"`` additionally admits arrivals
    at every pipeline-stage boundary of the in-flight batch.

    ``explore_in_batch`` lets ODIN exploration trials ride a formed
    batch pipelined instead of draining the pipeline for a serial
    trial — the trial config serves the whole dispatch, the measurement
    the explorer consumes is unchanged.
    """

    mode: str = "continuous"
    max_batch: int = 8
    buckets: Optional[LengthBuckets] = None
    explore_in_batch: bool = False

    def __post_init__(self):
        if self.mode not in BATCHING_MODES:
            raise ValueError(f"batching mode must be one of "
                             f"{BATCHING_MODES}, got {self.mode!r}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, "
                             f"got {self.max_batch}")

    @property
    def continuous(self) -> bool:
        return self.mode == "continuous"

    def padded_lengths(self, lengths: Optional[np.ndarray]
                       ) -> Optional[np.ndarray]:
        """Per-query padded (bucket-edge) lengths, or ``None`` when the
        run carries no length information (every query then shares one
        implicit bucket)."""
        if lengths is None:
            return None
        lengths = np.asarray(lengths, dtype=np.int64)
        if self.buckets is None:
            return lengths
        return self.buckets.pad_many(lengths)


def resolve_buckets(buckets, seq: Optional[int] = None
                    ) -> Optional[LengthBuckets]:
    """Accept a :class:`LengthBuckets`, an edge list, a ``"pow2:lo:hi"``
    spec, ``"single"`` (one bucket at ``seq``), or ``None``."""
    if buckets is None or isinstance(buckets, LengthBuckets):
        return buckets
    if isinstance(buckets, str):
        if buckets == "single":
            if seq is None:
                raise ValueError("buckets='single' needs a sequence "
                                 "length to pad to")
            return LengthBuckets.single(seq)
        if buckets.startswith("pow2:"):
            parts = buckets.split(":")
            if len(parts) != 3:
                raise ValueError(f"pow2 bucket spec must be "
                                 f"'pow2:lo:hi', got {buckets!r}")
            return LengthBuckets.pow2(int(parts[1]), int(parts[2]))
        return LengthBuckets([int(p) for p in buckets.split(",")])
    return LengthBuckets(buckets)


def resolve_batching(batching, max_batch: int = 8, buckets=None,
                     explore_in_batch: bool = False,
                     seq: Optional[int] = None) -> Optional[BatchFormer]:
    """One construction path for the batch former.

    ``batching`` may be ``None`` / ``"none"`` (no former — the exact
    pre-batching code path), a mode name (``"drain"`` /
    ``"continuous"``), or a ready :class:`BatchFormer`.
    """
    if batching is None or batching == "none":
        return None
    if isinstance(batching, BatchFormer):
        return batching
    return BatchFormer(mode=batching, max_batch=max_batch,
                       buckets=resolve_buckets(buckets, seq=seq),
                       explore_in_batch=explore_in_batch)


Batching = Union[None, str, BatchFormer]
