"""Built-in arrival processes: closed, poisson, bursty, trace.

All open-loop generators are seeded and deterministic — calling
``inter_arrivals`` twice returns the identical array, so a run can be
reproduced from ``(workload name, kwargs, seed)`` alone.  Rates are in
queries per time unit of the driver (wall-clock seconds for the live
engine, database units for the simulator).

* ``closed`` — today's back-to-back behaviour and the default: each
  query arrives the instant the pipeline frees up; no queueing, results
  bit-compatible with the pre-workloads drivers.
* ``poisson`` — open-loop memoryless arrivals at ``rate`` (the classic
  serving-benchmark process; e.g. Clockwork's SLO evaluations).
* ``bursty`` — a 2-state Markov-modulated Poisson process (MMPP):
  exponentially-distributed ON phases at ``burst_rate`` alternate with
  OFF phases at ``base_rate`` (MArk-style flash crowds).
* ``trace`` — replays a recorded inter-arrival array (cycled if the run
  is longer than the trace).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.workloads.registry import register_workload


@register_workload("closed")
class ClosedLoopWorkload:
    """Closed loop: the next query arrives exactly when the pipeline can
    take it.  This is the paper's §4 methodology (a saturated stream of
    back-to-back queries) and the behaviour of the pre-workloads
    ``simulate()`` / ``ServingEngine.serve()``."""

    open_loop = False

    def inter_arrivals(self, num_queries: int) -> Optional[np.ndarray]:
        return None


@register_workload("poisson")
class PoissonWorkload:
    """Open-loop Poisson arrivals: i.i.d. exponential inter-arrivals."""

    open_loop = True

    def __init__(self, rate: float, seed: int = 0):
        if rate <= 0:
            raise ValueError(f"poisson rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.seed = int(seed)

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.exponential(1.0 / self.rate, size=num_queries)


@register_workload("bursty")
class BurstyWorkload:
    """2-state MMPP: Poisson at ``burst_rate`` during exponentially long
    ON phases (mean ``mean_burst``), at ``base_rate`` during OFF phases
    (mean ``mean_gap``).  ``base_rate=0`` gives pure on/off traffic.

    Long-run mean rate = (mean_burst * burst_rate + mean_gap *
    base_rate) / (mean_burst + mean_gap).
    """

    open_loop = True

    def __init__(self, burst_rate: float, base_rate: float = 0.0,
                 mean_burst: float = 1.0, mean_gap: float = 1.0,
                 seed: int = 0):
        if burst_rate <= 0:
            raise ValueError(f"burst_rate must be > 0, got {burst_rate}")
        if base_rate < 0:
            raise ValueError(f"base_rate must be >= 0, got {base_rate}")
        if mean_burst <= 0 or mean_gap <= 0:
            raise ValueError("phase durations must be > 0")
        self.burst_rate = float(burst_rate)
        self.base_rate = float(base_rate)
        self.mean_burst = float(mean_burst)
        self.mean_gap = float(mean_gap)
        self.seed = int(seed)

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        arrivals = np.empty(num_queries)
        count = 0
        t = 0.0
        on = True        # start inside a burst so short runs see one
        while count < num_queries:
            mean_len = self.mean_burst if on else self.mean_gap
            rate = self.burst_rate if on else self.base_rate
            phase_end = t + rng.exponential(mean_len)
            if rate > 0:
                while count < num_queries:
                    gap = rng.exponential(1.0 / rate)
                    if t + gap >= phase_end:
                        break
                    t += gap
                    arrivals[count] = t
                    count += 1
            t = phase_end
            on = not on
        return np.diff(arrivals, prepend=0.0)


@register_workload("trace")
class TraceWorkload:
    """Replays a recorded inter-arrival array (e.g. from production
    logs), cycling it when the run outlasts the trace."""

    open_loop = True

    def __init__(self, inter_arrivals: Sequence[float]):
        gaps = np.asarray(inter_arrivals, dtype=float)
        if gaps.ndim != 1 or len(gaps) == 0:
            raise ValueError("inter_arrivals must be a non-empty 1-D array")
        if np.any(gaps < 0):
            raise ValueError("inter_arrivals must be non-negative")
        self.gaps = gaps

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        reps = -(-num_queries // len(self.gaps))      # ceil division
        return np.tile(self.gaps, reps)[:num_queries]
