"""Built-in arrival processes: closed, poisson, bursty, diurnal, ramp, trace.

All open-loop generators are seeded and deterministic — calling
``inter_arrivals`` twice returns the identical array, so a run can be
reproduced from ``(workload name, kwargs, seed)`` alone.  Rates are in
queries per time unit of the driver (wall-clock seconds for the live
engine, database units for the simulator).

* ``closed`` — today's back-to-back behaviour and the default: each
  query arrives the instant the pipeline frees up; no queueing, results
  bit-compatible with the pre-workloads drivers.
* ``poisson`` — open-loop memoryless arrivals at ``rate`` (the classic
  serving-benchmark process; e.g. Clockwork's SLO evaluations).
* ``bursty`` — a 2-state Markov-modulated Poisson process (MMPP):
  exponentially-distributed ON phases at ``burst_rate`` alternate with
  OFF phases at ``base_rate`` (MArk-style flash crowds).
* ``diurnal`` — inhomogeneous Poisson with a sinusoidal rate (the
  day/night swing production traces show); the traffic to demo a
  cluster router riding load swings (docs/CLUSTER.md).
* ``ramp`` — inhomogeneous Poisson whose rate climbs linearly from
  ``start_rate`` to ``end_rate`` over ``ramp_time`` then holds (load
  tests / launch ramps; finds the latency knee as load approaches
  capacity).
* ``trace`` — replays a recorded inter-arrival array (cycled if the run
  is longer than the trace).

The inhomogeneous generators (``diurnal``, ``ramp``) sample by
*thinning* (Lewis & Shedler): candidates arrive at the envelope rate
``rate_max`` and survive with probability ``rate(t) / rate_max`` —
exact for any bounded rate function, and vectorized in candidate
batches.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

import numpy as np

from repro.workloads.registry import register_workload


def _thinned_arrivals(num_queries: int, rate_fn: Callable[[np.ndarray],
                                                          np.ndarray],
                      rate_max: float, rng: np.random.Generator
                      ) -> np.ndarray:
    """Inter-arrival gaps of an inhomogeneous Poisson process.

    ``rate_fn`` maps an array of times to instantaneous rates in
    ``[0, rate_max]``.  Candidates are drawn in batches at ``rate_max``
    and thinned; draws happen in a fixed order, so the output is a
    pure function of the rng seed.
    """
    out = np.empty(num_queries)
    count = 0
    t = 0.0
    batch = max(256, num_queries)
    while count < num_queries:
        gaps = rng.exponential(1.0 / rate_max, size=batch)
        times = t + np.cumsum(gaps)
        keep = rng.random(batch) * rate_max < rate_fn(times)
        accepted = times[keep]
        take = min(len(accepted), num_queries - count)
        out[count:count + take] = accepted[:take]
        count += take
        # Resume after the last *candidate*, accepted or not — unless
        # the run is already full, in which case the tail is unused.
        t = float(times[-1])
    return np.diff(out, prepend=0.0)


@register_workload("closed")
class ClosedLoopWorkload:
    """Closed loop: the next query arrives exactly when the pipeline can
    take it.  This is the paper's §4 methodology (a saturated stream of
    back-to-back queries) and the behaviour of the pre-workloads
    ``simulate()`` / ``ServingEngine.serve()``."""

    open_loop = False

    def inter_arrivals(self, num_queries: int) -> Optional[np.ndarray]:
        return None


@register_workload("poisson")
class PoissonWorkload:
    """Open-loop Poisson arrivals: i.i.d. exponential inter-arrivals."""

    open_loop = True

    def __init__(self, rate: float, seed: int = 0):
        if rate <= 0:
            raise ValueError(f"poisson rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.seed = int(seed)

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.exponential(1.0 / self.rate, size=num_queries)


@register_workload("bursty")
class BurstyWorkload:
    """2-state MMPP: Poisson at ``burst_rate`` during exponentially long
    ON phases (mean ``mean_burst``), at ``base_rate`` during OFF phases
    (mean ``mean_gap``).  ``base_rate=0`` gives pure on/off traffic.

    Long-run mean rate = (mean_burst * burst_rate + mean_gap *
    base_rate) / (mean_burst + mean_gap).
    """

    open_loop = True

    def __init__(self, burst_rate: float, base_rate: float = 0.0,
                 mean_burst: float = 1.0, mean_gap: float = 1.0,
                 seed: int = 0):
        if burst_rate <= 0:
            raise ValueError(f"burst_rate must be > 0, got {burst_rate}")
        if base_rate < 0:
            raise ValueError(f"base_rate must be >= 0, got {base_rate}")
        if mean_burst <= 0 or mean_gap <= 0:
            raise ValueError("phase durations must be > 0")
        self.burst_rate = float(burst_rate)
        self.base_rate = float(base_rate)
        self.mean_burst = float(mean_burst)
        self.mean_gap = float(mean_gap)
        self.seed = int(seed)

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        arrivals = np.empty(num_queries)
        count = 0
        t = 0.0
        on = True        # start inside a burst so short runs see one
        while count < num_queries:
            mean_len = self.mean_burst if on else self.mean_gap
            rate = self.burst_rate if on else self.base_rate
            phase_end = t + rng.exponential(mean_len)
            if rate > 0:
                while count < num_queries:
                    gap = rng.exponential(1.0 / rate)
                    if t + gap >= phase_end:
                        break
                    t += gap
                    arrivals[count] = t
                    count += 1
            t = phase_end
            on = not on
        return np.diff(arrivals, prepend=0.0)


@register_workload("diurnal")
class DiurnalWorkload:
    """Sinusoidal-rate inhomogeneous Poisson: ``rate(t) = mean_rate *
    (1 + amplitude * sin(2π t / period + phase))``.

    ``amplitude`` in ``[0, 1)`` keeps the rate strictly positive
    (``amplitude=0`` degenerates to plain Poisson); ``period`` is the
    full day/night cycle in the driver's time unit; ``phase`` (radians)
    picks where in the cycle the run starts (default 0 = mid-climb
    toward the peak).
    """

    open_loop = True

    def __init__(self, mean_rate: float, period: float,
                 amplitude: float = 0.5, phase: float = 0.0,
                 seed: int = 0):
        if mean_rate <= 0:
            raise ValueError(f"mean_rate must be > 0, got {mean_rate}")
        if period <= 0:
            raise ValueError(f"period must be > 0, got {period}")
        if not 0.0 <= amplitude < 1.0:
            raise ValueError(f"amplitude must be in [0, 1), "
                             f"got {amplitude}")
        self.mean_rate = float(mean_rate)
        self.period = float(period)
        self.amplitude = float(amplitude)
        self.phase = float(phase)
        self.seed = int(seed)

    def rate_at(self, t):
        """Instantaneous arrival rate at time(s) ``t``."""
        return self.mean_rate * (
            1.0 + self.amplitude * np.sin(
                2.0 * math.pi * np.asarray(t) / self.period + self.phase))

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        rate_max = self.mean_rate * (1.0 + self.amplitude)
        return _thinned_arrivals(num_queries, self.rate_at, rate_max, rng)


@register_workload("ramp")
class RampWorkload:
    """Linear-ramp inhomogeneous Poisson: the rate climbs from
    ``start_rate`` to ``end_rate`` over ``ramp_time`` and holds there
    (ramp-down works too — ``end_rate < start_rate``)."""

    open_loop = True

    def __init__(self, start_rate: float, end_rate: float,
                 ramp_time: float, seed: int = 0):
        if start_rate < 0 or end_rate < 0:
            raise ValueError("rates must be >= 0")
        if max(start_rate, end_rate) <= 0:
            raise ValueError("at least one of start_rate/end_rate must "
                             "be > 0")
        if ramp_time <= 0:
            raise ValueError(f"ramp_time must be > 0, got {ramp_time}")
        self.start_rate = float(start_rate)
        self.end_rate = float(end_rate)
        self.ramp_time = float(ramp_time)
        self.seed = int(seed)

    def rate_at(self, t):
        """Instantaneous arrival rate at time(s) ``t``."""
        frac = np.clip(np.asarray(t) / self.ramp_time, 0.0, 1.0)
        return self.start_rate + (self.end_rate - self.start_rate) * frac

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        rate_max = max(self.start_rate, self.end_rate)
        return _thinned_arrivals(num_queries, self.rate_at, rate_max, rng)


@register_workload("trace")
class TraceWorkload:
    """Replays a recorded inter-arrival array (e.g. from production
    logs), cycling it when the run outlasts the trace."""

    open_loop = True

    def __init__(self, inter_arrivals: Sequence[float]):
        gaps = np.asarray(inter_arrivals, dtype=float)
        if gaps.ndim != 1 or len(gaps) == 0:
            raise ValueError("inter_arrivals must be a non-empty 1-D array")
        if np.any(gaps < 0):
            raise ValueError("inter_arrivals must be non-negative")
        self.gaps = gaps

    def inter_arrivals(self, num_queries: int) -> np.ndarray:
        reps = -(-num_queries // len(self.gaps))      # ceil division
        return np.tile(self.gaps, reps)[:num_queries]
