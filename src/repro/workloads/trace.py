"""The one per-run result type shared by simulator and live engine.

:class:`PipelineTrace` replaces the duplicated (and diverging) halves of
the old ``SimResult`` / ``ServeMetrics``: per-query arrays, rebalance
accounting, and the full metric surface (percentile latency, steady
throughput, SLO violations, queueing delay, offered vs. achieved load)
are computed identically whether the queries ran against the database
simulator or real JAX execution.  ``SimResult`` and ``ServeMetrics``
remain importable as deprecated aliases of this class.

Latency decomposition (open-loop workloads): ``latencies = queue_delays
+ service_latencies``.  Closed-loop runs have zero queue delay, so
``latencies`` is bit-identical to the pre-workloads per-query latency.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry.sketch import _percentile_sorted


@dataclasses.dataclass
class PipelineTrace:
    scheduler: str
    latencies: np.ndarray          # per query: queue delay + service time
    throughputs: np.ndarray        # per query: 1 / bottleneck stage time
    serial_mask: np.ndarray        # True where query was processed serially
    configs_trace: List[List[int]]
    num_rebalances: int
    total_trials: int
    mitigation_lengths: List[int]  # trials consumed per rebalancing phase
    workload: str = "closed"
    service_latencies: Optional[np.ndarray] = None  # = latencies when closed
    queue_delays: Optional[np.ndarray] = None       # zeros when closed
    arrival_times: Optional[np.ndarray] = None
    completion_times: Optional[np.ndarray] = None
    # In-system depth (queued + in-flight through the pipeline) seen at
    # each query's arrival; a saturated closed loop sits at ~num_stages.
    queue_depths: Optional[np.ndarray] = None
    peak_throughput: float = float("nan")  # interference-free optimum
    rc_throughputs: Optional[np.ndarray] = None  # per-query DP optimum
    # -- admission control / load shedding (repro.control) ------------------
    #: Name of the admission policy the run was served under.
    admission: str = "none"
    #: Latency objective (driver time units) the admission policy
    #: enforced; +inf when no objective was enforced (SLO attainment is
    #: then trivially 1 and goodput counts every admitted completion).
    slo_latency: float = float("inf")
    #: Arrival times of shed queries (empty = nothing shed).  The
    #: per-query arrays above only ever hold *admitted* queries.
    shed_arrivals: Optional[np.ndarray] = None
    # -- batch occupancy / padding accounting (docs/WORKLOADS.md) ------------
    #: Size of the dispatch each query rode in (1.0 for solo queries;
    #: ``None`` on traces built before batching existed — read as all-1).
    batch_sizes: Optional[np.ndarray] = None
    #: Padded tokens charged to each query (bucket-edge length, plus any
    #: batch-dimension padding charged to the dispatch head); zeros when
    #: the run carried no length information.
    padded_tokens: Optional[np.ndarray] = None
    #: Useful tokens per query (actual sequence length).
    actual_tokens: Optional[np.ndarray] = None
    # -- fault tolerance (repro.faults; docs/FAULTS.md) ----------------------
    #: Admitted queries that exhausted their retry budget (the
    #: per-query arrays never hold failed queries — they complete
    #: nothing).  ``availability = admitted-and-completed / admitted``.
    num_failed: int = 0
    #: Retry attempts made across the run (a query retried twice
    #: counts 2).
    num_retried: int = 0
    #: Dispatches that were hedged on a second replica (counted on the
    #: winning replica's trace).
    num_hedged: int = 0
    #: Occupancy charged for work that produced no completion: timed-out
    #: hangs and cancelled hedge losers (driver time units).
    wasted_time: float = 0.0
    #: Time this pipeline was crash-down (fault-plan clock units) plus,
    #: on cluster traces, breaker-open time stamped by the fleet loop.
    downtime: float = 0.0
    # -- QoS tiers (repro.qos; docs/QOS.md) ----------------------------------
    #: Tier names, index-aligned with :attr:`tier_ids`; ``None`` when
    #: the run had no tiers configured (every per-tier surface below is
    #: then absent and summaries carry no per-tier keys).
    tier_names: Optional[Tuple[str, ...]] = None
    #: Tier index of each admitted query.
    tier_ids: Optional[np.ndarray] = None
    #: Relative deadline (seconds from arrival) of each admitted query.
    tier_deadlines: Optional[np.ndarray] = None
    #: SLO value of each admitted query.
    tier_values: Optional[np.ndarray] = None
    #: Shed queries per tier (admission never ran them).
    shed_tier_counts: Optional[np.ndarray] = None
    #: Offered value lost to shedding (sum of shed queries' values).
    shed_value: float = 0.0
    #: Queries per tier the router downgraded to a small-model replica
    #: (cluster runs under the ``downgrade`` policy; docs/QOS.md).
    downgrade_tier_counts: Optional[np.ndarray] = None
    # -- sharded stage execution (repro.core.mesh; docs/SHARDING.md) ---------
    #: Total devices in the stage mesh; 0 = unsharded run (every mesh
    #: surface below is then absent and summaries carry no mesh keys).
    mesh_devices: int = 0
    #: Committed device assignment (devices per stage) after each
    #: rebalance, aligned with :attr:`configs_trace`; ``None`` unsharded.
    mesh_trace: Optional[List[List[int]]] = None
    #: Per-query fraction of the bottleneck stage's time spent in
    #: collectives; ``None`` unsharded.
    collective_fracs: Optional[np.ndarray] = None
    #: Times the committed assignment changed during the run.
    num_mesh_resizes: int = 0

    def __post_init__(self):
        n = len(self.latencies)
        if self.service_latencies is None:
            self.service_latencies = np.array(self.latencies, copy=True)
        if self.queue_delays is None:
            self.queue_delays = np.zeros(n)
        if self.queue_depths is None:
            self.queue_depths = np.zeros(n, dtype=int)
        if self.shed_arrivals is None:
            self.shed_arrivals = np.empty(0)
        else:
            self.shed_arrivals = np.asarray(self.shed_arrivals, dtype=float)
        if self.batch_sizes is None:
            self.batch_sizes = np.ones(n)
        if self.padded_tokens is None:
            self.padded_tokens = np.zeros(n)
        if self.actual_tokens is None:
            self.actual_tokens = np.zeros(n)
        if self.tier_names is not None:
            if (self.tier_ids is None or self.tier_deadlines is None
                    or self.tier_values is None):
                raise ValueError("a tiered trace needs tier_ids, "
                                 "tier_deadlines and tier_values")
            if self.shed_tier_counts is None:
                self.shed_tier_counts = np.zeros(len(self.tier_names),
                                                 dtype=np.int64)
        # Percentile reads share one sort per field (summary() alone
        # makes three; rows() adds more) — sorted once, cached here.
        self._sorted_cache: Dict[str, np.ndarray] = {}

    # -- percentiles (one sort per field, reused for every read) -------------
    def percentile(self, pct: float, field: str = "latencies") -> float:
        """Percentile of a per-query array field, from a cached sort.

        Bit-identical to ``np.percentile(getattr(self, field), pct)``
        (linear interpolation), but the O(n log n) sort happens once
        per field per trace instead of once per read.  NaN-safe: an
        empty trace (admission shed everything) reads as NaN instead
        of raising.
        """
        cached = self._sorted_cache.get(field)
        if cached is None:
            cached = np.sort(np.asarray(getattr(self, field)))
            self._sorted_cache[field] = cached
        return _percentile_sorted(cached, pct)

    # -- compat surface (old ServeMetrics field names) ----------------------
    @property
    def configs(self) -> List[List[int]]:
        """Alias of :attr:`configs_trace` (old ``ServeMetrics`` name)."""
        return self.configs_trace

    @property
    def stage_time_max(self) -> np.ndarray:
        """Per-query bottleneck stage time (old ``ServeMetrics`` field)."""
        return 1.0 / np.maximum(self.throughputs, 1e-12)

    # -- rebalance accounting ------------------------------------------------
    @property
    def rebalance_fraction(self) -> float:
        if not len(self.serial_mask):
            return float("nan")
        return float(np.mean(self.serial_mask))

    @property
    def steady_throughput(self) -> float:
        """Mean throughput over pipelined (non-exploration) queries — the
        pipeline's operating rate, which is what the paper's Fig. 6
        reports (exploration overhead is Fig. 8's separate metric)."""
        pipe = self.throughputs[~self.serial_mask]
        if len(pipe):
            return float(pipe.mean())
        if len(self.throughputs):
            return float(self.throughputs.mean())
        return float("nan")

    # -- latency -----------------------------------------------------------
    def tail_latency(self, pct: float = 99.0) -> float:
        return self.percentile(pct)

    @property
    def mean_queue_delay(self) -> float:
        if not len(self.queue_delays):
            return float("nan")
        return float(np.mean(self.queue_delays))

    # -- SLO --------------------------------------------------------------
    def slo_violations(self, slo_level: float,
                       reference: str = "peak") -> float:
        """Fraction of queries with throughput below slo_level × reference.

        NaN for an empty trace (nothing was admitted, so the fraction
        is undefined)."""
        if not len(self.throughputs):
            return float("nan")
        if reference == "peak":
            target = slo_level * self.peak_throughput
            return float(np.mean(self.throughputs < target))
        elif reference == "resource_constrained":
            if self.rc_throughputs is None:
                raise ValueError(
                    "this trace has no resource-constrained reference "
                    "(the executor provided no reference_throughput)")
            target = slo_level * self.rc_throughputs
            return float(np.mean(self.throughputs < target))
        raise ValueError(reference)

    # -- admission / shed accounting (docs/CONTROL.md) ----------------------
    @property
    def num_admitted(self) -> int:
        """Queries that entered (and ran through) the pipeline."""
        return len(self.latencies)

    @property
    def num_shed(self) -> int:
        """Queries the admission policy turned away."""
        return len(self.shed_arrivals)

    @property
    def num_offered(self) -> int:
        """All arrivals, admitted plus shed."""
        return self.num_admitted + self.num_shed

    @property
    def shed_rate(self) -> float:
        """Fraction of offered queries that were shed."""
        return self.num_shed / self.num_offered if self.num_offered else 0.0

    # -- fault accounting (repro.faults; docs/FAULTS.md) ---------------------
    @property
    def availability(self) -> float:
        """Completed ÷ admitted.  1.0 for a fault-free run; admitted
        queries that exhausted their retry budget lower it.  Shed
        queries are an admission decision, not a failure — they do not
        count against availability."""
        admitted = self.num_admitted + self.num_failed
        if not admitted:
            return float("nan")
        return self.num_admitted / admitted

    @property
    def wasted_work_frac(self) -> float:
        """Fraction of total pipeline occupancy that produced no
        completion (timed-out hangs, cancelled hedge losers)."""
        if self.wasted_time <= 0.0:
            return 0.0
        useful = float(np.sum(np.where(self.throughputs > 0,
                                       1.0 / np.maximum(self.throughputs,
                                                        1e-12), 0.0)))
        total = useful + self.wasted_time
        return self.wasted_time / total if total > 0 else 0.0

    @property
    def slo_attainment(self) -> float:
        """Fraction of *admitted* queries with latency within the
        admission policy's SLO (trivially 1.0 when no finite SLO was
        enforced; NaN for an empty trace)."""
        if not self.num_admitted:
            return float("nan")
        if not np.isfinite(self.slo_latency):
            return 1.0
        return float(np.mean(self.latencies <= self.slo_latency))

    @property
    def goodput_qps(self) -> float:
        """Completion rate of admitted queries that met the SLO — the
        control plane's figure of merit (InferLine's goodput).  Equals
        :attr:`achieved_load` when no SLO was enforced."""
        if not np.isfinite(self.slo_latency):
            return self.achieved_load
        if self.completion_times is None or len(self.completion_times) < 2:
            return float("nan")
        span = float(np.max(self.completion_times))
        if span <= 0:
            return float("inf")
        return float(np.sum(self.latencies <= self.slo_latency)) / span

    # -- batch occupancy / padding (docs/WORKLOADS.md) -----------------------
    @property
    def mean_batch_occupancy(self) -> float:
        """Mean dispatch size queries rode in (1.0 = everything solo)."""
        if not len(self.batch_sizes):
            return float("nan")
        return float(np.mean(self.batch_sizes))

    @property
    def padded_token_frac(self) -> float:
        """Fraction of executed tokens that were padding waste
        (``1 - actual/padded``); 0.0 when the run carried no length
        information (both totals are then zero)."""
        total = float(np.sum(self.padded_tokens))
        if total <= 0.0:
            return 0.0
        return 1.0 - float(np.sum(self.actual_tokens)) / total

    # -- QoS tiers (repro.qos; docs/QOS.md) ----------------------------------
    @property
    def deadline_met_mask(self) -> np.ndarray:
        """Per-admitted-query "completed within its deadline" mask
        (all-True rows for tiers without a deadline)."""
        if self.tier_deadlines is None:
            raise ValueError("this trace has no tiers configured")
        return self.latencies <= self.tier_deadlines

    @property
    def offered_value(self) -> float:
        """Total SLO value offered to the run: admitted plus shed."""
        if self.tier_values is None:
            return float("nan")
        return float(np.sum(self.tier_values)) + float(self.shed_value)

    @property
    def realized_value(self) -> float:
        """SLO value actually earned: the summed value of admitted
        queries that completed within their deadlines.  The QoS figure
        of merit — what value-aware shedding maximizes under overload
        (a shed or late query earns nothing)."""
        if self.tier_values is None:
            return float("nan")
        return float(np.sum(self.tier_values[self.deadline_met_mask]))

    def tier_summary(self) -> Dict[str, float]:
        """Per-tier metric keys (docs/QOS.md): served/shed counts,
        p50/p99 latency, deadline attainment (met ÷ offered — shed
        queries count against the tier), downgrades when a downgrade
        router ran, plus the fleet-level offered/realized value.
        Empty when the run had no tiers configured."""
        if self.tier_names is None:
            return {}
        nan = float("nan")
        out = {"offered_value": self.offered_value,
               "realized_value": self.realized_value}
        met_mask = self.deadline_met_mask
        for i, name in enumerate(self.tier_names):
            m = self.tier_ids == i
            cnt = int(np.count_nonzero(m))
            shed = int(self.shed_tier_counts[i])
            offered = cnt + shed
            lat = np.sort(self.latencies[m])
            out[f"tier_{name}_num"] = float(cnt)
            out[f"tier_{name}_shed"] = float(shed)
            out[f"tier_{name}_p50_latency_s"] = _percentile_sorted(lat, 50)
            out[f"tier_{name}_p99_latency_s"] = _percentile_sorted(lat, 99)
            out[f"tier_{name}_deadline_attainment"] = (
                int(np.count_nonzero(met_mask & m)) / offered
                if offered else nan)
            if self.downgrade_tier_counts is not None:
                out[f"tier_{name}_downgraded"] = float(
                    self.downgrade_tier_counts[i])
        return out

    # -- sharded stage execution (docs/SHARDING.md) ---------------------------
    @property
    def mean_collective_frac(self) -> float:
        """Mean bottleneck-stage collective share across queries (NaN
        on an unsharded or empty trace)."""
        if self.collective_fracs is None or not len(self.collective_fracs):
            return float("nan")
        return float(np.mean(self.collective_fracs))

    # -- offered vs. achieved load ------------------------------------------
    @property
    def offered_load(self) -> float:
        """Arrival rate over the run (queries / time unit), counting
        shed queries — offered load is what arrived, not what ran."""
        if self.arrival_times is None or len(self.arrival_times) < 2:
            return float("nan")
        span = float(self.arrival_times[-1])
        if self.num_shed:
            span = max(span, float(np.max(self.shed_arrivals)))
        return self.num_offered / span if span > 0 else float("inf")

    @property
    def achieved_load(self) -> float:
        """Completion rate over the run (queries / time unit)."""
        if self.completion_times is None or len(self.completion_times) < 2:
            return float("nan")
        span = float(np.max(self.completion_times))
        return (len(self.completion_times) / span if span > 0
                else float("inf"))

    def load_profile(self, num_windows: int = 20
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-window offered vs. achieved rates.

        Returns ``(window_starts, offered_qps, achieved_qps)`` over
        ``num_windows`` equal windows spanning the run; shows where an
        open-loop burst outran the pipeline (offered > achieved) and the
        later drain (achieved > offered).
        """
        if self.arrival_times is None or self.completion_times is None:
            raise ValueError("no arrival ledger on this trace")
        end = float(max(np.max(self.completion_times),
                        self.arrival_times[-1]))
        edges = np.linspace(0.0, end if end > 0 else 1.0, num_windows + 1)
        width = edges[1] - edges[0]
        offered = np.histogram(self.arrival_times, bins=edges)[0] / width
        achieved = np.histogram(self.completion_times, bins=edges)[0] / width
        return edges[:-1], offered, achieved

    # -- the one summary dict ------------------------------------------------
    #: SLO level summary() reports violations at (throughput >= 90% of
    #: the interference-free peak; paper Fig. 9's mid-range level).
    SUMMARY_SLO_LEVEL = 0.9

    def summary(self) -> Dict[str, float]:
        """Flat metric dict — identical keys for sim and live runs.

        NaN-safe on an empty trace (zero admitted queries): every
        per-query statistic reads as NaN; counts and shed accounting
        stay exact.  Percentile keys share one cached sort per field
        (:meth:`percentile`) instead of re-sorting per read.
        """
        n = self.num_admitted
        nan = float("nan")
        peak_known = np.isfinite(self.peak_throughput)
        out = {
            "mean_latency_s": float(self.latencies.mean()) if n else nan,
            "p50_latency_s": self.percentile(50),
            "p99_latency_s": self.tail_latency(99),
            "mean_service_latency_s": (float(self.service_latencies.mean())
                                       if n else nan),
            "mean_queue_delay_s": self.mean_queue_delay,
            "p99_queue_delay_s": self.percentile(99, "queue_delays"),
            "mean_throughput_qps": (float(self.throughputs.mean())
                                    if n else nan),
            "steady_throughput_qps": self.steady_throughput,
            "peak_throughput_qps": float(self.peak_throughput),
            "offered_load_qps": self.offered_load,
            "achieved_load_qps": self.achieved_load,
            "slo_violations": (self.slo_violations(self.SUMMARY_SLO_LEVEL)
                               if peak_known else float("nan")),
            "rebalances": self.num_rebalances,
            "serial_frac": self.rebalance_fraction,
            # -- admission control / goodput (docs/CONTROL.md) -------------
            "num_shed": float(self.num_shed),
            "shed_rate": self.shed_rate,
            "goodput_qps": self.goodput_qps,
            "slo_attainment": self.slo_attainment,
            "slo_latency_s": float(self.slo_latency),
            # -- batch occupancy / padding (docs/WORKLOADS.md) --------------
            "mean_batch_occupancy": self.mean_batch_occupancy,
            "p99_batch_occupancy": self.percentile(99, "batch_sizes"),
            "padded_token_frac": self.padded_token_frac,
            # -- fault tolerance (repro.faults; docs/FAULTS.md) -------------
            "num_failed": float(self.num_failed),
            "num_retried": float(self.num_retried),
            "num_hedged": float(self.num_hedged),
            "availability": self.availability,
            "wasted_work_frac": self.wasted_work_frac,
            "downtime_s": float(self.downtime),
        }
        # Per-tier keys appear only on tiered runs, so no-tier
        # summaries are byte-identical to pre-QoS summaries.
        if self.tier_names is not None:
            out.update(self.tier_summary())
        # Mesh keys appear only on sharded runs (same gating rule).
        if self.mesh_devices > 0:
            out["mesh_devices"] = float(self.mesh_devices)
            out["num_mesh_resizes"] = float(self.num_mesh_resizes)
            out["mean_collective_frac"] = self.mean_collective_frac
            out["p99_collective_frac"] = (
                self.percentile(99, "collective_fracs")
                if self.collective_fracs is not None else nan)
        return out
