"""Traffic workloads + the unified run loop (see docs/WORKLOADS.md).

A :class:`Workload` (arrival process) plus a :class:`QueryExecutor`
(driver-specific query execution) feed :func:`run_pipeline`, the one
traffic-driven event loop shared by the database simulator and the live
JAX serving engine; every run yields the unified :class:`PipelineTrace`
metric surface.
"""
from repro.workloads.base import (  # noqa: F401
    BatchRecord,
    DispatchRecord,
    QueryExecutor,
    QueryRecord,
    Workload,
)
from repro.workloads.batching import (  # noqa: F401
    BatchFormer,
    LengthBuckets,
    resolve_batching,
    resolve_buckets,
)
from repro.workloads.generators import (  # noqa: F401
    BurstyWorkload,
    ClosedLoopWorkload,
    DiurnalWorkload,
    PoissonWorkload,
    RampWorkload,
    TraceWorkload,
)
from repro.workloads.lengths import (  # noqa: F401
    available_lengths,
    make_lengths,
    register_lengths,
    resolve_lengths,
    with_lengths,
)
from repro.workloads.registry import (  # noqa: F401
    available_workloads,
    make_workload,
    register_workload,
    unregister_workload,
    workload_class,
)
from repro.workloads.runner import (  # noqa: F401
    DEFAULT_MAX_CHUNK,
    PipelineRunner,
    resolve_workload,
    run_pipeline,
)
from repro.workloads.trace import PipelineTrace  # noqa: F401
