from repro.pipeline.executor import (  # noqa: F401
    LocalPipelineExecutor,
    MeasuredTimeSource,
    stage_bounds,
)
