from repro.pipeline.executor import (  # noqa: F401
    LocalPipelineExecutor,
    MeasuredTimeSource,
    MixedSequenceLengthError,
    next_pow2,
    stage_bounds,
)
