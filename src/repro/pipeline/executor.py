"""Recompile-free pipeline-stage executor (DESIGN.md §2).

The model's blocks are stacked along a leading ``[num_blocks, ...]`` axis;
a pipeline stage executes blocks ``[lo, hi)`` via ``lax.fori_loop`` with
*traced* bounds, so the ODIN rebalancer can move blocks between stages
without triggering any recompilation — trial configurations run at full
speed (beyond-paper: the paper processes queries serially during
rebalancing; its exhaustive-search alternative took 42.5 minutes).

This executor runs every stage on the host device(s) sequentially and
*measures* per-stage wall time — exactly the signal ODIN consumes.  The
SPMD multi-stage schedule (each stage on its own mesh slice) lives in
``repro.pipeline.spmd``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.layers import embed, rms_norm, unembed


def stage_bounds(config: Sequence[int]) -> List[tuple]:
    """[(lo, hi)] block ranges per stage for a layer-count config."""
    out, lo = [], 0
    for c in config:
        out.append((lo, lo + c))
        lo += c
    return out


class LocalPipelineExecutor:
    """Executes a stage-partitioned model, timing each stage.

    One jitted ``stage_fn(params, x, positions, lo, hi)`` serves *all*
    stages and *all* configurations — bounds are runtime arguments.
    """

    def __init__(self, cfg: ModelConfig, params: Dict):
        self.cfg = cfg
        self.params = params
        cfg_ = cfg

        @jax.jit
        def stage_fn(params, x, positions, lo, hi):
            def body(i, h):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                h, _ = blk.block_forward(bp, cfg_, h, positions)
                return h
            return jax.lax.fori_loop(lo, hi, body, x)

        @jax.jit
        def embed_fn(params, tokens):
            return embed(params["embed"], tokens)

        @jax.jit
        def head_fn(params, x):
            x = rms_norm(x, params["final_norm"]["scale"], cfg_.rms_eps)
            return unembed(params["head"], x)

        self._stage_fn = stage_fn
        self._embed_fn = embed_fn
        self._head_fn = head_fn

    # -- warmup ---------------------------------------------------------------
    def warmup(self, batch: int, seq: int) -> None:
        x = jnp.zeros((batch, seq), jnp.int32)
        self.run_query(x, [self.cfg.num_blocks])

    # -- execution --------------------------------------------------------------
    def run_query(self, tokens: jnp.ndarray, config: Sequence[int],
                  slowdowns: Optional[Sequence[float]] = None
                  ) -> tuple:
        """Run one query through the pipeline of ``config``.

        Returns (logits, stage_times_seconds ndarray).  ``slowdowns``
        emulates co-located interference per EP by stretching the
        measured stage time (sleep), physically delaying the pipeline —
        the scheduler only ever sees measured times.
        """
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self._embed_fn(self.params, tokens)
        x.block_until_ready()
        times = np.zeros(len(config))
        for s, (lo, hi) in enumerate(stage_bounds(config)):
            t0 = time.perf_counter()
            x = self._stage_fn(self.params, x, positions,
                               jnp.int32(lo), jnp.int32(hi))
            x.block_until_ready()
            dt = time.perf_counter() - t0
            if slowdowns is not None and slowdowns[s] > 1.0:
                extra = dt * (slowdowns[s] - 1.0)
                time.sleep(extra)
                dt += extra
            times[s] = dt
        logits = self._head_fn(self.params, x)
        logits.block_until_ready()
        return logits, times

    def measure_block_times(self, tokens: jnp.ndarray,
                            repeats: int = 3) -> np.ndarray:
        """Per-block clean execution times (database column 0)."""
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self._embed_fn(self.params, tokens)
        L = self.cfg.num_blocks
        times = np.zeros((repeats, L))
        for r in range(repeats):
            h = x
            for i in range(L):
                h.block_until_ready()
                t0 = time.perf_counter()
                h = self._stage_fn(self.params, h, positions,
                                   jnp.int32(i), jnp.int32(i + 1))
                h.block_until_ready()
                times[r, i] = time.perf_counter() - t0
        return times.min(axis=0)


class MeasuredTimeSource:
    """StageTimeSource over real measured per-block times + live scenarios.

    Bridges the executor world to the ODIN/LLS controllers: stage time =
    sum of its blocks' measured clean times × the EP's current slowdown.
    """

    def __init__(self, block_times: np.ndarray, slowdowns: np.ndarray):
        self.block_times = np.asarray(block_times, float)
        self.slowdowns = np.asarray(slowdowns, float)  # per EP

    def stage_times(self, config: Sequence[int]) -> np.ndarray:
        out = np.zeros(len(config))
        lo = 0
        for i, c in enumerate(config):
            out[i] = self.block_times[lo:lo + c].sum() * self.slowdowns[i]
            lo += c
        return out
