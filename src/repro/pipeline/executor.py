"""Recompile-free pipeline-stage executor (DESIGN.md §2).

The model's blocks are stacked along a leading ``[num_blocks, ...]`` axis;
a pipeline stage executes blocks ``[lo, hi)`` via ``lax.fori_loop`` with
*traced* bounds, so the ODIN rebalancer can move blocks between stages
without triggering any recompilation — trial configurations run at full
speed (beyond-paper: the paper processes queries serially during
rebalancing; its exhaustive-search alternative took 42.5 minutes).

This executor runs every stage on the host device(s) sequentially and
*measures* per-stage wall time — exactly the signal ODIN consumes.  The
SPMD multi-stage schedule (each stage on its own mesh slice) lives in
``repro.pipeline.spmd``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.layers import embed, rms_norm, unembed
# Canonical home is the typed serving-error hierarchy
# (repro.util.errors); re-exported here for backward compatibility.
from repro.util.errors import MixedSequenceLengthError  # noqa: F401


def stage_bounds(config: Sequence[int]) -> List[tuple]:
    """[(lo, hi)] block ranges per stage for a layer-count config."""
    out, lo = [], 0
    for c in config:
        out.append((lo, lo + c))
        lo += c
    return out


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (max(int(n), 1) - 1).bit_length()


class LocalPipelineExecutor:
    """Executes a stage-partitioned model, timing each stage.

    One jitted ``stage_fn(params, x, positions, lo, hi)`` serves *all*
    stages and *all* configurations — bounds are runtime arguments.
    """

    def __init__(self, cfg: ModelConfig, params: Dict):
        self.cfg = cfg
        self.params = params
        cfg_ = cfg

        @jax.jit
        def stage_fn(params, x, positions, lo, hi):
            def body(i, h):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                h, _ = blk.block_forward(bp, cfg_, h, positions)
                return h
            return jax.lax.fori_loop(lo, hi, body, x)

        @jax.jit
        def embed_fn(params, tokens):
            return embed(params["embed"], tokens)

        @jax.jit
        def head_fn(params, x):
            x = rms_norm(x, params["final_norm"]["scale"], cfg_.rms_eps)
            return unembed(params["head"], x)

        self._stage_fn = stage_fn
        self._embed_fn = embed_fn
        self._head_fn = head_fn
        self._warmed = set()       # (batch, seq) shapes already compiled

    # -- warmup ---------------------------------------------------------------
    def warmup(self, batch: int, seq: int) -> None:
        x = jnp.zeros((batch, seq), jnp.int32)
        self.run_query(x, [self.cfg.num_blocks])
        self._warmed.add((batch, seq))

    def ensure_warm(self, batch: int, seq: int) -> None:
        """Compile the (batch, seq) input shape if not yet seen.

        The executor is recompile-free across *configurations* (stage
        bounds are runtime arguments), but XLA still specializes on the
        input shape — so a batched dispatch must never pay (or measure)
        a first-shape compile inside the serving loop."""
        if (batch, seq) not in self._warmed:
            self.warmup(batch, seq)

    def warm_buckets(self, seq_buckets: Sequence[int],
                     max_batch: int) -> None:
        """Pre-compile exactly the length-bucketed dispatch shapes.

        Bucketed dispatch pads every batch to a power-of-two row count
        and every query to its length-bucket edge, so the full shape set
        is ``{1, 2, 4, .., next_pow2(max_batch)} x seq_buckets`` — a
        small closed set, keeping ``_warmed`` bounded however many
        distinct raw ``(batch, seq)`` combinations the traffic offers.
        """
        rows, r = [], 1
        cap = next_pow2(max_batch)
        while r <= cap:
            rows.append(r)
            r *= 2
        for seq in seq_buckets:
            for b in rows:
                self.ensure_warm(b, int(seq))

    # -- execution --------------------------------------------------------------
    def _device_bounds(self, config: Sequence[int]) -> List[tuple]:
        """Stage bounds as committed device scalars.

        Hoisted out of the timed stage loop so the host→device transfer
        of the ``lo``/``hi`` runtime arguments — and its jitter — never
        lands inside a stage-time measurement the scheduler consumes.
        """
        bounds = [(jnp.int32(lo), jnp.int32(hi))
                  for lo, hi in stage_bounds(config)]
        for lo, hi in bounds:
            lo.block_until_ready()
            hi.block_until_ready()
        return bounds

    def embed_tokens(self, tokens: jnp.ndarray) -> tuple:
        """Embed ``[B, S]`` tokens -> (hidden ``[B, S, D]``, positions).

        Blocks until the embedding is on device so the first stage's
        measured time never includes the embed dispatch.
        """
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self._embed_fn(self.params, tokens)
        x.block_until_ready()
        return x, positions

    def run_stages(self, x: jnp.ndarray, positions: jnp.ndarray,
                   config: Sequence[int], lo_stage: int, hi_stage: int,
                   slowdowns: Optional[Sequence[float]] = None,
                   bounds: Optional[List[tuple]] = None) -> tuple:
        """Run stages ``[lo_stage, hi_stage)`` of ``config`` over ``x``.

        The stage-granular entry point for continuous batching: a batch
        can stop at any stage boundary, absorb newly arrived (embedded +
        caught-up) queries along the batch axis, and resume — all with
        the same jitted ``stage_fn``, since stage bounds and the batch
        dimension are runtime arguments (no recompile).

        Returns ``(x, times)`` where ``times[s]`` is the measured wall
        time of stage ``lo_stage + s`` (slowdown-stretched like
        :meth:`run_query`).  ``bounds`` accepts the precomputed
        :meth:`_device_bounds` result so per-stage callers don't re-pay
        the host->device commit between boundaries.
        """
        if bounds is None:
            bounds = self._device_bounds(config)
        times = np.zeros(hi_stage - lo_stage)
        for s in range(lo_stage, hi_stage):
            lo, hi = bounds[s]
            t0 = time.perf_counter()
            x = self._stage_fn(self.params, x, positions, lo, hi)
            x.block_until_ready()
            dt = time.perf_counter() - t0
            if slowdowns is not None and slowdowns[s] > 1.0:
                extra = dt * (slowdowns[s] - 1.0)
                time.sleep(extra)
                dt += extra
            times[s - lo_stage] = dt
        return x, times

    def head(self, x: jnp.ndarray) -> jnp.ndarray:
        """Final norm + unembed, blocked until ready."""
        logits = self._head_fn(self.params, x)
        logits.block_until_ready()
        return logits

    def run_query(self, tokens: jnp.ndarray, config: Sequence[int],
                  slowdowns: Optional[Sequence[float]] = None
                  ) -> tuple:
        """Run one query through the pipeline of ``config``.

        Returns (logits, stage_times_seconds ndarray).  ``slowdowns``
        emulates co-located interference per EP by stretching the
        measured stage time (sleep), physically delaying the pipeline —
        the scheduler only ever sees measured times.
        """
        bounds = self._device_bounds(config)
        x, positions = self.embed_tokens(tokens)
        x, times = self.run_stages(x, positions, config, 0, len(config),
                                   slowdowns=slowdowns, bounds=bounds)
        logits = self.head(x)
        return logits, times

    def run_batch(self, queries: Sequence[jnp.ndarray],
                  config: Sequence[int],
                  slowdowns: Optional[Sequence[float]] = None
                  ) -> tuple:
        """Run a stacked batch of queries through the pipeline once.

        ``queries`` are ``[B_i, S]`` token arrays with one shared
        sequence length; they are concatenated along the batch axis and
        every stage executes a single time over the stacked batch — the
        same jitted ``stage_fn`` (the batch dimension was always a
        runtime size), so a burst of B queries pays one set of stage
        dispatches + device syncs instead of B of them.

        Returns (logits ``[sum(B_i), S, V]``, stage_times ndarray).
        Stage times cover the whole batch; per-query attribution is the
        caller's policy (the serving engine divides by the batch size).

        A single-query batch is forwarded as-is (no concat, no copy);
        mixed sequence lengths raise :class:`MixedSequenceLengthError`
        naming every query's length.
        """
        if len(queries) == 0:
            raise ValueError("run_batch needs at least one query")
        if len(queries) == 1:
            tokens = queries[0]
        else:
            lengths = [int(t.shape[-1]) for t in queries]
            if len(set(lengths)) != 1:
                raise MixedSequenceLengthError(lengths)
            tokens = jnp.concatenate(list(queries), axis=0)
        return self.run_query(tokens, config, slowdowns=slowdowns)

    def measure_block_times(self, tokens: jnp.ndarray,
                            repeats: int = 3) -> np.ndarray:
        """Per-block clean execution times (database column 0)."""
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self._embed_fn(self.params, tokens)
        L = self.cfg.num_blocks
        # One committed device scalar per block boundary, outside the
        # timed region (same hoist as run_query).
        edges = [jnp.int32(i) for i in range(L + 1)]
        for e in edges:
            e.block_until_ready()
        times = np.zeros((repeats, L))
        for r in range(repeats):
            h = x
            for i in range(L):
                h.block_until_ready()
                t0 = time.perf_counter()
                h = self._stage_fn(self.params, h, positions,
                                   edges[i], edges[i + 1])
                h.block_until_ready()
                times[r, i] = time.perf_counter() - t0
        return times.min(axis=0)


class MeasuredTimeSource:
    """StageTimeSource over real measured per-block times + live scenarios.

    Bridges the executor world to the ODIN/LLS controllers: stage time =
    sum of its blocks' measured clean times × the EP's current slowdown.
    Polled on every exploration trial, so the per-stage reduction is one
    ``np.add.reduceat`` over the config's block offsets instead of a
    Python loop over stages.

    With a :class:`~repro.core.mesh.MeshSpec` attached the source
    additionally models mesh-sliced stages (docs/SHARDING.md): the
    measured compute time divides by the stage's device count and a
    modeled collective term is added via
    :func:`~repro.core.mesh.mesh_stage_times` — the same cost model the
    simulator uses, so a live scheduler reasons over (boundary, slice)
    moves from measured data.  ``assignment`` is the committed slice
    vector (the runtime keeps it synced); ``coll_factor`` is the live
    collective-contention estimate (1.0 when quiet).  ``mesh=None``
    (the default) touches none of this — byte-identical behavior to the
    pre-mesh source.
    """

    def __init__(self, block_times: np.ndarray, slowdowns: np.ndarray,
                 mesh=None, coll_times: Optional[np.ndarray] = None,
                 assignment: Optional[Sequence[int]] = None,
                 coll_factor: float = 1.0):
        self.block_times = np.asarray(block_times, float)
        self.slowdowns = np.asarray(slowdowns, float)  # per EP
        self.mesh = mesh  # MeshSpec or None
        self.coll_times = (np.asarray(coll_times, float)
                           if coll_times is not None
                           else (mesh.layer_costs(len(self.block_times))
                                 if mesh is not None else None))
        self.assignment = (list(assignment) if assignment is not None
                           else None)
        self.coll_factor = float(coll_factor)

    def _compute_times(self, config: Sequence[int]) -> np.ndarray:
        counts = np.asarray(config, dtype=np.int64)
        out = np.zeros(len(counts))
        nz = counts > 0
        if nz.any():
            starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
            # reduceat over the offsets of non-empty stages only: each
            # segment then ends exactly at the next non-empty stage's
            # start (empty stages contribute no blocks and stay 0).
            out[nz] = np.add.reduceat(self.block_times, starts[nz])
        return out * self.slowdowns

    def stage_times(self, config: Sequence[int],
                    assignment: Optional[Sequence[int]] = None
                    ) -> np.ndarray:
        compute = self._compute_times(config)
        if self.mesh is None:
            return compute
        a = assignment if assignment is not None else self.assignment
        if a is None:
            return compute
        from repro.core.mesh import mesh_stage_times
        return mesh_stage_times(compute, config, a, self.mesh,
                                self.coll_factor,
                                layer_costs=self.coll_times)

    def collective_frac(self, config: Sequence[int],
                        assignment: Optional[Sequence[int]] = None
                        ) -> float:
        """Bottleneck stage's modeled collective share (the live
        ``collective_frac`` trace column); 0.0 unsharded."""
        if self.mesh is None:
            return 0.0
        a = assignment if assignment is not None else self.assignment
        if a is None:
            return 0.0
        from repro.core.mesh import collective_frac as _frac
        return _frac(self._compute_times(config), config, a, self.mesh,
                     self.coll_factor, layer_costs=self.coll_times)
