"""SPMD pipeline-parallel execution (bind-to-stage on mesh slices).

Maps the paper's execution places onto mesh slices: a ``stage`` mesh axis
partitions the chips into N execution places; each holds a *padded* tile
of the stacked block parameters (``[cap, ...]`` per stage, cap ≥ the
largest stage ODIN may create).  The live block count per stage is a
runtime argument, so ODIN rebalancing = a cheap weight reshuffle + new
count vector — never a recompile.

The schedule is GPipe-style fill/drain over M microbatches with
activations handed to the next stage via ``jax.lax.ppermute`` each step;
empty stages (count 0) forward activations untouched, which is exactly
the paper's "pipeline may shorten by one stage" semantics.

The remaining mesh axes (e.g. ``model``) shard each stage's computation
(operator parallelism *within* an execution place, paper §2).
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models import blocks as blk


def stage_mesh(num_stages: int, *, model_parallel: int = 1):
    """The SPMD pipeline's mesh, built through the single shared
    constructor in :mod:`repro.launch.mesh` (this module used to build
    its own; docs/SHARDING.md).  ``stage`` partitions the chips into
    execution places; ``model`` is operator parallelism within one."""
    from repro.launch.mesh import make_stage_mesh
    return make_stage_mesh(num_stages, model_parallel=model_parallel)


def pack_stage_params(stacked_blocks: Dict, config: Sequence[int],
                      cap: int) -> Dict:
    """Repack [L, ...] stacked blocks into [num_stages, cap, ...] tiles.

    Stage s's tile holds its blocks [lo_s, hi_s) in slots [0, count_s);
    the padding slots keep whatever block data fills them (they are never
    executed).  On rebalance this is re-materialized — the weight-
    migration cost the paper pays when moving layers between EPs.
    """
    L = jax.tree.leaves(stacked_blocks)[0].shape[0]
    n = len(config)

    def pack(p):
        tiles = []
        lo = 0
        for c in config:
            idx = (jnp.arange(cap) + lo).clip(0, L - 1)
            tiles.append(p[idx])
            lo += c
        return jnp.stack(tiles)  # [n, cap, ...]

    return jax.tree.map(pack, stacked_blocks)


def make_pipeline_fn(cfg: ModelConfig, mesh, *, stage_axis: str = "stage",
                     num_microbatches: int = 4, cap: int):
    """Build the jit-able pipelined forward.

    Signature: fn(stage_params, counts, inputs) -> outputs
      stage_params: [n_stages, cap, ...] pytree (sharded over stage_axis)
      counts:       [n_stages] int32 live block counts
      inputs:       [M, mb, S, d] embedded microbatches (replicated)
      outputs:      [M, mb, S, d] final hidden states (replicated)
    """
    n_stages = mesh.shape[stage_axis]
    M = num_microbatches

    def stage_compute(params_local, x, positions, count):
        def body(i, h):
            bp = jax.tree.map(lambda p: p[i], params_local)
            h, _ = blk.block_forward(bp, cfg, h, positions)
            return h
        return jax.lax.fori_loop(0, count, body, x)

    def pipeline(stage_params, counts, inputs):
        # local views: stage_params [1, cap, ...]; counts [1]; inputs full
        sp = jax.tree.map(lambda p: p[0], stage_params)
        stage_id = jax.lax.axis_index(stage_axis)
        count = counts[stage_id]
        _, mb, S, d = inputs.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (mb, S))
        is_last = stage_id == n_stages - 1

        T = n_stages + M - 1
        x0 = jnp.zeros((mb, S, d), inputs.dtype)
        out0 = jnp.zeros((M, mb, S, d), inputs.dtype)

        def step(t, carry):
            x_cur, outputs = carry
            mb_idx = t - stage_id
            active = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 pulls microbatch t from the input queue
            feed = jax.lax.dynamic_index_in_dim(
                inputs, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            x_in = jnp.where(stage_id == 0, feed, x_cur)
            y = stage_compute(sp, x_in, positions, count)
            y = jnp.where(active, y, x_in)
            # hand activations to the next stage
            x_next = jax.lax.ppermute(
                y, stage_axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage commits its finished microbatch
            upd = jax.lax.dynamic_update_index_in_dim(
                outputs, y, jnp.clip(mb_idx, 0, M - 1), axis=0)
            outputs = jnp.where(is_last & active, upd, outputs)
            return (x_next, outputs)

        _, outputs = jax.lax.fori_loop(0, T, step, (x0, out0))
        # broadcast the last stage's buffer to every stage
        mask = jnp.where(is_last, 1.0, 0.0).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, stage_axis)

    # model-parallel sub-sharding of the per-stage tiles is delegated to
    # pjit on the caller side; the shard_map here only owns stage_axis.
    fn = shard_map(
        pipeline, mesh=mesh,
        in_specs=(P(stage_axis), P(), P()),
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(fn)


def pipelined_forward(cfg: ModelConfig, mesh, stacked_blocks: Dict,
                      config: Sequence[int], inputs: jnp.ndarray, *,
                      cap: int, stage_axis: str = "stage",
                      num_microbatches: int = 4) -> jnp.ndarray:
    """Convenience wrapper: pack + run.  inputs: [M, mb, S, d] embedded."""
    stage_params = pack_stage_params(stacked_blocks, config, cap)
    counts = jnp.asarray(list(config), jnp.int32)
    fn = make_pipeline_fn(cfg, mesh, stage_axis=stage_axis,
                          num_microbatches=num_microbatches, cap=cap)
    return fn(stage_params, counts, inputs)


class SpmdPipelineExecutor:
    """Physical sharded-stage execution — the SPMD counterpart of
    :class:`repro.pipeline.executor.LocalPipelineExecutor`.

    Each pipeline stage owns one slice of a :func:`stage_mesh`; a query
    runs embed → GPipe-schedule stages (``ppermute`` hand-offs between
    slices) → head, and ODIN rebalancing stays recompile-free because
    the live block counts are runtime arguments.  Requires
    ``jax.device_count() >= num_stages`` (guard call sites; the serving
    loop's scheduler-side mesh *model* in
    :class:`~repro.pipeline.executor.MeasuredTimeSource` needs no
    devices and is the default — docs/SHARDING.md).
    """

    def __init__(self, cfg: ModelConfig, params: Dict, num_stages: int, *,
                 cap: int = 0, model_parallel: int = 1,
                 num_microbatches: int = 1):
        if jax.device_count() < num_stages * model_parallel:
            raise ValueError(
                f"{num_stages}x{model_parallel} mesh needs "
                f">= {num_stages * model_parallel} devices, have "
                f"{jax.device_count()}")
        self.cfg = cfg
        self.params = params
        self.mesh = stage_mesh(num_stages, model_parallel=model_parallel)
        self.cap = int(cap) if cap else cfg.num_blocks
        self.M = int(num_microbatches)
        self._fn = make_pipeline_fn(cfg, self.mesh,
                                    num_microbatches=self.M, cap=self.cap)

    def run_query(self, tokens: jnp.ndarray,
                  config: Sequence[int]) -> jnp.ndarray:
        """Run ``[B, S]`` tokens through the sharded pipeline of
        ``config``; returns logits ``[B, S, V]``.  ``B`` is padded up to
        a multiple of the microbatch count, padding rows dropped."""
        from repro.models.layers import embed, rms_norm, unembed
        B, S = tokens.shape
        mb = -(-B // self.M)  # rows per microbatch, padded up
        if mb * self.M > B:
            tokens = jnp.concatenate(
                [tokens, jnp.zeros((mb * self.M - B, S), tokens.dtype)])
        x = embed(self.params["embed"], tokens)
        inputs = x.reshape(self.M, mb, S, -1)
        stage_params = pack_stage_params(self.params["blocks"], config,
                                         self.cap)
        counts = jnp.asarray(list(config), jnp.int32)
        out = self._fn(stage_params, counts, inputs)
        h = out.reshape(mb * self.M, S, -1)[:B]
        h = rms_norm(h, self.params["final_norm"]["scale"],
                     self.cfg.rms_eps)
        return unembed(self.params["head"], h)
